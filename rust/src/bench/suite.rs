//! Deterministic shuffle/executor benchmark suite — the perf trajectory
//! behind `hetcdc bench-json` and the CI `bench-smoke` gate.
//!
//! Every scenario is a fixed-seed job on a fixed heterogeneous cluster;
//! the recorded metrics (payload/wire bytes, messages, the simulator's
//! virtual phase times) are **deterministic** — identical on every
//! machine, thread count, and run — so the emitted `BENCH_shuffle.json`
//! is diffable and a committed baseline can gate regressions exactly.
//! Wall-clock timing is optional (`--timing`) and never part of the gate.
//!
//! Each scenario also executes in all three [`ExecMode`]s — serial,
//! shard-parallel, and batch-pipelined — and fails loudly (with a typed
//! [`HetcdcError`], never a panic) on any divergence, so the CI bench
//! job doubles as a continuous determinism check of the sharded and
//! pipelined executors. Under `--timing`, each scenario also records a
//! steady-state pipelined multi-batch wall-clock sample next to the
//! single-batch one (`wall_pipelined`), the batches/sec trajectory of
//! the serving path.

use crate::bench::harness::{Bench, BenchResult};
use crate::engine::{ExecConfig, ExecMode, Executor, JobBuilder, NativeBackend};
use crate::error::{HetcdcError, Result};
use crate::model::cluster::{ClusterSpec, NodeSpec};
use crate::model::job::{JobSpec, ShuffleMode, WorkloadKind};
use crate::net::{Dropout, Erase, FaultSpec, Straggle, Topology};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Bench artifact schema version (`BENCH_shuffle.json`).
pub const SCHEMA_VERSION: usize = 1;

/// One fixed-shape benchmark point.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub storage: &'static [u64],
    pub n_files: u64,
    pub workload: WorkloadKind,
    /// Placer registry name (`"auto"` resolves by K).
    pub placer: &'static str,
    /// Coder registry name; `None` uses the placer's default.
    pub coder: Option<&'static str>,
    pub mode: ShuffleMode,
    /// Network topology of the scenario's cluster (`Shared` = the
    /// historical single broadcast medium). Topology changes the
    /// simulated schedule only — byte/message/round counts of a `-rack`
    /// scenario are identical to its shared-medium sibling, which the
    /// suite tests assert.
    pub topology: Topology,
    /// Fault model of the scenario's cluster. Straggler jitter changes
    /// the simulated schedule only (a `-straggle` scenario's byte, message
    /// and round counts equal its fault-free twin's — asserted by the
    /// suite tests); `repair:f=N` changes the plan shape (extra coded
    /// repair rounds).
    pub faults: FaultSpec,
    /// When set, the scenario additionally drops this node after the
    /// normal run, re-plans on the survivors via
    /// [`crate::engine::Plan::replan_without`], executes the recovery
    /// plan, and records the recovery cost deltas.
    pub drop_node: Option<usize>,
}

/// Fault-free marker for the scenario table ([`FaultSpec::default`],
/// spelled as a `const` so the table rows stay literal).
const NO_FAULTS: FaultSpec = FaultSpec {
    straggle: None,
    repair: 0,
    erase: None,
    dropout: None,
};

/// The committed straggle point: deterministic per-node jitter, amplitude
/// large enough that the jittered Map tail provably stalls some send.
const STRAGGLE: FaultSpec = FaultSpec {
    straggle: Some(Straggle {
        seed: 0xBE7C,
        amp: 3.0,
    }),
    repair: 0,
    erase: None,
    dropout: None,
};

/// The committed degraded-decode point: tolerate one lost broadcast.
const REPAIR1: FaultSpec = FaultSpec {
    straggle: None,
    repair: 1,
    erase: None,
    dropout: None,
};

/// The committed runtime-erasure point: seeded per-broadcast erasures at
/// p=0.05 on an f=1 repaired plan. Single losses are absorbed by the
/// repair rounds at decode time; anything beyond tolerance is recovered
/// by metered retransmission rounds — both outcomes land in the
/// artifact's `recovery` counters.
const ERASE_REPAIR1: FaultSpec = FaultSpec {
    straggle: None,
    repair: 1,
    erase: Some(Erase::Seeded {
        seed: 0x5EED,
        p: 0.05,
    }),
    dropout: None,
};

/// The committed mid-run dropout point: node 0 is lost after two batches
/// of the multi-batch run; the executor re-plans on the survivors and
/// resumes the remaining batches on the recovery plan.
const MIDRUN_DROP: FaultSpec = FaultSpec {
    straggle: None,
    repair: 0,
    erase: None,
    dropout: Some(Dropout {
        node: 0,
        at_batch: 2,
    }),
};

/// The committed suite: K ∈ {3, 5, 8, 12, 16} heterogeneous clusters,
/// coded and uncoded, TeraSort plus a WordCount point. Order and names
/// are stable — the baseline comparison keys on `name`. K=3 uses
/// Theorem 1, K=5 the §V LP; K=8 runs four ways — the storage-oblivious
/// memory-sharing placement, the dual-certified exact §V LP (cyclic
/// shift-orbit seeding keeps the master debug-sized), the combinatorial
/// grid with its own coder, and the *same grid placement* under greedy
/// pairing, so the grid coder's gain over pairwise XOR is **measured**
/// in the committed artifact, not asserted. K ∈ {12, 16} extend the
/// combinatorial design into the larger-K cascaded regime; their
/// exact-LP points live in [`extended_suite`] (release `bench-json`
/// territory — the K=12/16 masters are too heavy for the 4×-repeated
/// debug test runs).
#[rustfmt::skip]
pub fn default_suite() -> Vec<Scenario> {
    use ShuffleMode::{Coded, Uncoded};
    use WorkloadKind::{TeraSort, WordCount};
    vec![
        Scenario { name: "k3-terasort-coded", storage: &[6, 7, 7], n_files: 12, workload: TeraSort, placer: "auto", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k3-terasort-uncoded", storage: &[6, 7, 7], n_files: 12, workload: TeraSort, placer: "auto", coder: None, mode: Uncoded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k3-wordcount-coded", storage: &[4, 8, 12], n_files: 12, workload: WordCount, placer: "auto", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k5-terasort-coded", storage: &[3, 4, 5, 6, 7], n_files: 10, workload: TeraSort, placer: "auto", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k5-terasort-uncoded", storage: &[3, 4, 5, 6, 7], n_files: 10, workload: TeraSort, placer: "auto", coder: None, mode: Uncoded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k8-terasort-coded", storage: &[2, 3, 3, 4, 4, 5, 5, 6], n_files: 8, workload: TeraSort, placer: "oblivious", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k8-terasort-uncoded", storage: &[2, 3, 3, 4, 4, 5, 5, 6], n_files: 8, workload: TeraSort, placer: "oblivious", coder: None, mode: Uncoded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        // Combinatorial grid design (q=2, r=4: gain 3) vs greedy pairing
        // (gain <= 2) on the identical placement — the measured coding
        // gain the acceptance gate checks.
        Scenario { name: "k8-terasort-combinatorial", storage: &[4, 4, 5, 5, 6, 6, 7, 7], n_files: 8, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k8-terasort-grid-greedy", storage: &[4, 4, 5, 5, 6, 6, 7, 7], n_files: 8, workload: TeraSort, placer: "combinatorial", coder: Some("greedy"), mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        // Exact §V LP at K=8: cap-free dual-certified placement — the
        // artifact records the solver's work counters (plan_build.lp_solver)
        // and gates dropped_collections at 0.
        Scenario { name: "k8-terasort-lp-exact", storage: &[4, 4, 5, 5, 6, 6, 7, 7], n_files: 8, workload: TeraSort, placer: "lp-general", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        // Larger-K combinatorial regimes: K=12 (q=3, r=4) and K=16
        // (q=2, r=8) — shapes no enumeration-based coder reaches.
        Scenario { name: "k12-terasort-combinatorial", storage: &[4, 4, 4, 5, 5, 5, 6, 6, 6, 7, 7, 7], n_files: 12, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k16-terasort-combinatorial", storage: &[8, 8, 9, 9, 10, 10, 11, 11, 8, 8, 9, 9, 10, 10, 11, 11], n_files: 16, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None },
        // Rack-switched twins of the combinatorial scenarios: identical
        // storage/job, 4:1 oversubscribed rack trunks. Byte, message, and
        // round counts must match the shared sibling exactly; only the
        // simulated schedule (makespan) improves, because the coder's q
        // node-disjoint transversal groups per round run concurrently.
        Scenario { name: "k8-terasort-combinatorial-rack", storage: &[4, 4, 5, 5, 6, 6, 7, 7], n_files: 8, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Rack { racks: 2, oversub: 4.0 }, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k12-terasort-combinatorial-rack", storage: &[4, 4, 4, 5, 5, 5, 6, 6, 6, 7, 7, 7], n_files: 12, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Rack { racks: 3, oversub: 4.0 }, faults: NO_FAULTS, drop_node: None },
        Scenario { name: "k16-terasort-combinatorial-rack", storage: &[8, 8, 9, 9, 10, 10, 11, 11, 8, 8, 9, 9, 10, 10, 11, 11], n_files: 16, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Rack { racks: 4, oversub: 4.0 }, faults: NO_FAULTS, drop_node: None },
        // Fault-injection twins of the K=8 combinatorial scenario.
        // Straggle: identical bytes/messages/rounds, only the simulated
        // schedule stretches (asserted by the suite tests). Repair f=1:
        // the plan itself grows verified coded repair rounds, so its
        // byte/round costs are the *price of loss tolerance*, measured in
        // the committed artifact. Dropout: after the normal run, node 0
        // is dropped, the survivors are re-planned, and the recovery cost
        // (bytes/rounds/makespan deltas) is recorded. Runtime erasure:
        // seeded per-broadcast losses on the f=1 repaired plan — decoded
        // output stays bit-identical to the fault-free run, and the
        // artifact records how the losses were absorbed (erased count,
        // retransmit rounds, recovery bytes, makespan delta vs an
        // erase-stripped twin). Mid-run dropout: node 0 dies between
        // batches of the pipelined run; the executor re-plans on the
        // survivors and the same survivor-plan recovery cost is recorded.
        Scenario { name: "k8-terasort-combinatorial-straggle", storage: &[4, 4, 5, 5, 6, 6, 7, 7], n_files: 8, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Shared, faults: STRAGGLE, drop_node: None },
        Scenario { name: "k8-terasort-combinatorial-repair1", storage: &[4, 4, 5, 5, 6, 6, 7, 7], n_files: 8, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Shared, faults: REPAIR1, drop_node: None },
        Scenario { name: "k8-terasort-dropout", storage: &[4, 4, 5, 5, 6, 6, 7, 7], n_files: 8, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: Some(0) },
        Scenario { name: "k8-terasort-combinatorial-erase", storage: &[4, 4, 5, 5, 6, 6, 7, 7], n_files: 8, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Shared, faults: ERASE_REPAIR1, drop_node: None },
        Scenario { name: "k8-terasort-midrun-dropout", storage: &[4, 4, 5, 5, 6, 6, 7, 7], n_files: 8, workload: TeraSort, placer: "combinatorial", coder: None, mode: Coded, topology: Topology::Shared, faults: MIDRUN_DROP, drop_node: None },
    ]
}

/// [`default_suite`] plus the large-K exact-LP points — the suite
/// `bench-json` actually runs. The K=12 and K=16 masters (cyclic-seeded,
/// dual-certified) solve in seconds in release builds but would dominate
/// the 4×-repeated debug test runs, so they live here rather than in the
/// default (test-visible) suite. Names and order extend the default
/// suite, so a default-suite baseline sees them as new scenarios.
#[rustfmt::skip]
pub fn extended_suite() -> Vec<Scenario> {
    use ShuffleMode::Coded;
    use WorkloadKind::TeraSort;
    let mut suite = default_suite();
    suite.push(Scenario { name: "k12-terasort-lp-exact", storage: &[4, 4, 4, 5, 5, 5, 6, 6, 6, 7, 7, 7], n_files: 12, workload: TeraSort, placer: "lp-general", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None });
    suite.push(Scenario { name: "k16-terasort-lp-exact", storage: &[4, 4, 4, 4, 5, 5, 5, 5, 6, 6, 6, 6, 7, 7, 7, 7], n_files: 12, workload: TeraSort, placer: "lp-general", coder: None, mode: Coded, topology: Topology::Shared, faults: NO_FAULTS, drop_node: None });
    suite
}

impl Scenario {
    /// EC2-flavored heterogeneous cluster derived deterministically from
    /// the node index: cycling uplinks and map rates, fixed latency.
    pub fn cluster(&self) -> ClusterSpec {
        ClusterSpec {
            nodes: self
                .storage
                .iter()
                .enumerate()
                .map(|(i, &m)| NodeSpec {
                    name: format!("bench{i}"),
                    storage: m,
                    uplink_mbps: 450.0 + 150.0 * (i % 4) as f64,
                    map_files_per_s: 120.0 * (1 + i % 3) as f64,
                })
                .collect(),
            latency_ms: 0.5,
            topology: self.topology,
            faults: self.faults.clone(),
        }
    }

    /// Small fixed-seed job (t and data sizes chosen so the whole suite
    /// runs in seconds even in debug builds).
    pub fn job(&self) -> JobSpec {
        let mut job = match self.workload {
            WorkloadKind::TeraSort => JobSpec::terasort(self.n_files),
            WorkloadKind::WordCount => JobSpec::wordcount(self.n_files),
        };
        job.t = 8;
        job.keys_per_file = 32;
        if job.workload == WorkloadKind::WordCount {
            job.vocab = 64;
        }
        job.seed = 0xBE7C;
        job
    }
}

/// Deterministic shape of one scenario's plan **construction** — counts,
/// never timings, so the artifact stays byte-reproducible. Wall-clock
/// plan-build comparisons live in `bench_shuffle` (`--timing` territory);
/// this section is what the baseline can diff: a build-path regression
/// that changes the IR's round/group/broadcast structure shows up here.
#[derive(Clone, Copy, Debug)]
pub struct PlanBuildStats {
    pub rounds: u64,
    pub groups: u64,
    pub broadcasts: u64,
}

impl PlanBuildStats {
    pub fn of(shuffle: &crate::coding::plan::ShufflePlan) -> Self {
        PlanBuildStats {
            rounds: shuffle.round_count() as u64,
            groups: shuffle.group_count() as u64,
            broadcasts: shuffle.n_broadcasts() as u64,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("groups".into(), Json::Num(self.groups as f64));
        m.insert("broadcasts".into(), Json::Num(self.broadcasts as f64));
        Json::Obj(m)
    }
}

/// Recovery cost of a fault scenario — dropout and/or runtime erasure.
/// For dropout the absolute metrics are the survivor plan's (one serial
/// batch on the re-planned survivors) and the deltas compare it to the
/// pre-drop plan; for runtime erasure they are the faulted run's own
/// metrics and the deltas compare it to an erase-stripped twin of the
/// same plan (so `delta_makespan_s` is exactly the schedule cost of
/// recovery). All deterministic — part of the diffable artifact.
#[derive(Clone, Copy, Debug)]
pub struct RecoveryStats {
    /// Dropped node — present on dropout records, `None` on
    /// erasure-only records.
    pub dropped_node: Option<usize>,
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    pub rounds: u64,
    pub makespan_s: f64,
    /// Deltas vs the fault-free reference (positive = recovery costs
    /// more).
    pub delta_payload_bytes: f64,
    pub delta_rounds: f64,
    pub delta_makespan_s: f64,
    /// Runtime-erasure counters (from the serial run's [`crate::net::NetReport`]) —
    /// present only when the scenario has an `erase` clause, so dropout
    /// and legacy artifacts stay byte-identical.
    pub erased_broadcasts: Option<u64>,
    pub retransmit_rounds: Option<u64>,
    pub recovery_bytes: Option<u64>,
}

impl RecoveryStats {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        if let Some(n) = self.dropped_node {
            m.insert("dropped_node".into(), Json::Num(n as f64));
        }
        m.insert("payload_bytes".into(), Json::Num(self.payload_bytes as f64));
        m.insert("wire_bytes".into(), Json::Num(self.wire_bytes as f64));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("makespan_s".into(), Json::Num(self.makespan_s));
        m.insert("delta_payload_bytes".into(), Json::Num(self.delta_payload_bytes));
        m.insert("delta_rounds".into(), Json::Num(self.delta_rounds));
        m.insert("delta_makespan_s".into(), Json::Num(self.delta_makespan_s));
        if let Some(e) = self.erased_broadcasts {
            m.insert("erased_broadcasts".into(), Json::Num(e as f64));
        }
        if let Some(r) = self.retransmit_rounds {
            m.insert("retransmit_rounds".into(), Json::Num(r as f64));
        }
        if let Some(b) = self.recovery_bytes {
            m.insert("recovery_bytes".into(), Json::Num(b as f64));
        }
        Json::Obj(m)
    }
}

/// Deterministic measurements of one scenario (plus optional wall-clock).
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub name: String,
    pub k: usize,
    pub n_files: u64,
    pub workload: &'static str,
    pub placer: String,
    pub coder: String,
    pub mode: &'static str,
    pub sp: u32,
    pub messages: u64,
    /// Shuffle rounds of the plan's IR — gated against the baseline so a
    /// coder silently degrading to one giant round fails loudly.
    pub rounds: u64,
    pub payload_bytes: u64,
    pub wire_bytes: u64,
    pub load_equations: f64,
    pub map_time_s: f64,
    pub shuffle_time_s: f64,
    /// Concurrent schedule length of the shuffle (the net simulator's
    /// `elapsed_s`): equal to `shuffle_time_s` on the shared medium,
    /// smaller on switched topologies where link-disjoint groups of one
    /// round overlap. Gated against the baseline like the byte totals.
    pub makespan_s: f64,
    /// Serial, parallel, and pipelined execution produced bit-identical
    /// outputs and network reports (always true — a divergence aborts
    /// the suite).
    pub modes_identical: bool,
    /// Plan-construction shape (rounds/groups/broadcasts — counts only,
    /// timestamp-free).
    pub plan_build: PlanBuildStats,
    /// Perfect collections the placement's enumeration dropped, summed
    /// over subsystems. Serialized only when nonzero (so pre-exact
    /// artifacts stay byte-identical) and gated like `rounds`: a
    /// baseline without the field reads as 0, and a scenario regressing
    /// from 0 fails the baseline comparison.
    pub dropped_collections: u64,
    /// Exact §V LP work counters — recorded only for exact-LP scenarios,
    /// serialized as `plan_build.lp_solver`. Deterministic like every
    /// other `plan_build` field.
    pub lp_solver: Option<crate::placement::lp_general::LpWorkStats>,
    /// Total straggler-induced schedule wait — recorded (and serialized)
    /// only for scenarios with a straggle spec, so fault-free artifacts
    /// stay byte-identical to pre-fault ones.
    pub straggler_delay_s: Option<f64>,
    /// Fault recovery cost — recorded (and serialized) only for
    /// scenarios with a `drop_node`, a `drop:` clause, or an `erase:`
    /// clause.
    pub recovery: Option<RecoveryStats>,
    /// Wall-clock of one parallel batch (nondeterministic, optional).
    pub wall: Option<BenchResult>,
    /// Wall-clock of one pipelined [`PIPELINE_BATCHES`]-batch run — the
    /// steady-state batches/sec sample (nondeterministic, optional).
    pub wall_pipelined: Option<BenchResult>,
}

/// Batches per pipelined timing sample (and per pipelined determinism
/// check): enough for the pipeline to reach steady state, small enough
/// for the suite to stay quick in debug builds.
pub const PIPELINE_BATCHES: u64 = 4;

impl ScenarioResult {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("k".into(), Json::Num(self.k as f64));
        m.insert("n_files".into(), Json::Num(self.n_files as f64));
        m.insert("workload".into(), Json::Str(self.workload.into()));
        m.insert("placer".into(), Json::Str(self.placer.clone()));
        m.insert("coder".into(), Json::Str(self.coder.clone()));
        m.insert("mode".into(), Json::Str(self.mode.into()));
        m.insert("sp".into(), Json::Num(self.sp as f64));
        m.insert("messages".into(), Json::Num(self.messages as f64));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("payload_bytes".into(), Json::Num(self.payload_bytes as f64));
        m.insert("wire_bytes".into(), Json::Num(self.wire_bytes as f64));
        m.insert("load_equations".into(), Json::Num(self.load_equations));
        m.insert("map_time_s".into(), Json::Num(self.map_time_s));
        m.insert("shuffle_time_s".into(), Json::Num(self.shuffle_time_s));
        m.insert("makespan_s".into(), Json::Num(self.makespan_s));
        m.insert("modes_identical".into(), Json::Bool(self.modes_identical));
        let mut plan_build = self.plan_build.to_json();
        if let (Json::Obj(pb), Some(stats)) = (&mut plan_build, &self.lp_solver) {
            pb.insert("lp_solver".into(), stats.to_json());
        }
        m.insert("plan_build".into(), plan_build);
        // Omitted-when-trivial fields: dropped_collections appears only
        // when the enumeration actually truncated, so cap-free artifacts
        // (and pre-exact baselines) read identically as 0.
        if self.dropped_collections > 0 {
            m.insert(
                "dropped_collections".into(),
                Json::Num(self.dropped_collections as f64),
            );
        }
        // Fault fields are omitted when no fault spec / no dropout is
        // configured: fault-free artifacts stay byte-identical.
        if let Some(d) = self.straggler_delay_s {
            m.insert("straggler_delay_s".into(), Json::Num(d));
        }
        if let Some(r) = &self.recovery {
            m.insert("recovery".into(), r.to_json());
        }
        if let Some(w) = &self.wall {
            m.insert("wall".into(), w.to_json());
        }
        if let Some(w) = &self.wall_pipelined {
            m.insert("wall_pipelined".into(), w.to_json());
        }
        Json::Obj(m)
    }
}

/// Two batch reports agree on every deterministic metric, bit for bit.
fn reports_identical(a: &crate::engine::RunReport, b: &crate::engine::RunReport) -> bool {
    a.verified == b.verified
        && a.payload_bytes == b.payload_bytes
        && a.wire_bytes == b.wire_bytes
        && a.messages == b.messages
        && a.shuffle_time_s.to_bits() == b.shuffle_time_s.to_bits()
        && a.map_time_s.to_bits() == b.map_time_s.to_bits()
        && a.max_abs_err.to_bits() == b.max_abs_err.to_bits()
        && a.replanned_without == b.replanned_without
}

/// Run one scenario: build the plan, execute serial, parallel, and
/// pipelined, verify bit-identical three-way equivalence, record the
/// deterministic metrics. All failure paths return a typed
/// [`HetcdcError`] — a malformed scenario fails the gate with a message,
/// never a panic.
pub fn run_scenario(
    sc: &Scenario,
    threads: usize,
    timing: Option<&Bench>,
) -> Result<ScenarioResult> {
    let cluster = sc.cluster();
    let job = sc.job();
    // The bench's thread budget drives plan construction too; built
    // plans are bit-identical at every thread count, so the artifact
    // stays byte-reproducible (asserted by the determinism test below).
    let mut builder = JobBuilder::new(&cluster, &job)
        .placer(sc.placer)
        .mode(sc.mode)
        .threads(threads);
    if let Some(coder) = sc.coder {
        builder = builder.coder(coder);
    }
    let plan = builder.build()?;

    // One config drives all three executors (cfg.faults stays None, so
    // each meters under the plan's own fault spec).
    let cfg = ExecConfig::default().threads(threads);
    let mut be = NativeBackend;
    let mut serial = Executor::with_config(&plan, cfg.clone())?;
    let r_serial = serial.run_batch(&mut be, job.seed)?;
    let mut parallel = Executor::with_config(&plan, cfg.clone().mode(ExecMode::Parallel))?;
    let r_parallel = parallel.run_batch(&mut be, job.seed)?;

    let diverged = |mode: &str, what: &str| {
        Err(HetcdcError::Shuffle(format!(
            "scenario {}: serial/{mode} divergence in {what}",
            sc.name,
        )))
    };
    if !r_serial.verified || !r_parallel.verified {
        return Err(HetcdcError::Backend(format!(
            "scenario {}: oracle verification failed",
            sc.name
        )));
    }
    if !reports_identical(&r_serial, &r_parallel) {
        return diverged("parallel", "batch report");
    }
    if serial.net_report() != parallel.net_report() {
        return diverged("parallel", "NetReport");
    }
    let n_sub = plan.alloc.n_sub();
    let k = cluster.k();
    // Every (node, group, subfile) IV slot of two executors agrees —
    // both the bytes and the known/unknown status.
    let ivs_identical = |a: &Executor, b: &Executor| {
        (0..k).all(|node| {
            (0..k).all(|g| {
                (0..n_sub).all(|sub| {
                    let iv = crate::coding::plan::IvId { group: g, sub };
                    a.iv(node, iv) == b.iv(node, iv)
                })
            })
        })
    };
    if !ivs_identical(&serial, &parallel) {
        return diverged("parallel", "decoded IV bytes");
    }

    // Pipelined multi-batch run vs the same batches run serially: the
    // steady-state serving path must be bit-identical, batch by batch.
    let seeds: Vec<u64> = (0..PIPELINE_BATCHES).map(|b| job.seed.wrapping_add(b)).collect();
    let mut pipelined = Executor::with_config(&plan, cfg.clone().mode(ExecMode::Pipelined))?;
    let piped = pipelined.run_batches(&mut be, &seeds)?;
    let mut serial_ref = Executor::with_config(&plan, cfg.clone())?;
    let serial_batches = serial_ref.run_batches(&mut be, &seeds)?;
    for (b, (rp, rs)) in piped.iter().zip(&serial_batches).enumerate() {
        if !rp.verified || !reports_identical(rp, rs) {
            return diverged("pipelined", &format!("batch {b} report"));
        }
    }
    if pipelined.net_report() != serial_ref.net_report() {
        return diverged("pipelined", "NetReport");
    }
    if !ivs_identical(&serial_ref, &pipelined) {
        return diverged("pipelined", "decoded IV bytes");
    }

    // Optional wall-clock sampling. The timed closures cannot return a
    // Result through the harness, so errors are captured and surfaced as
    // a typed failure instead of panicking mid-bench.
    let mut wall = None;
    let mut wall_pipelined = None;
    if let Some(cfg) = timing {
        let mut timing_err: Option<HetcdcError> = None;
        let w = crate::bench::harness::bench_fn(sc.name, cfg, || {
            match parallel.run_batch(&mut be, job.seed) {
                Ok(r) => r.payload_bytes,
                Err(e) => {
                    timing_err.get_or_insert(e);
                    0
                }
            }
        });
        if let Some(e) = timing_err.take() {
            return Err(HetcdcError::Backend(format!(
                "scenario {}: timed batch failed: {e}",
                sc.name
            )));
        }
        wall = Some(w);
        let pname = format!("{} (pipelined x{PIPELINE_BATCHES})", sc.name);
        let wp = crate::bench::harness::bench_fn(&pname, cfg, || {
            match pipelined.run_batches(&mut be, &seeds) {
                Ok(rs) => rs.iter().map(|r| r.payload_bytes).sum::<u64>(),
                Err(e) => {
                    timing_err.get_or_insert(e);
                    0
                }
            }
        });
        if let Some(e) = timing_err {
            return Err(HetcdcError::Backend(format!(
                "scenario {}: timed pipelined run failed: {e}",
                sc.name
            )));
        }
        wall_pipelined = Some(wp);
    }

    // Dropout recovery: re-plan on the survivors (reusing their placed
    // subfiles), execute one serial batch of the recovery plan, and meter
    // its cost against the pre-drop plan. Deterministic like everything
    // above. A mid-run `drop:` clause records the same survivor-plan
    // metrics — its actual switchover is exercised by the multi-batch
    // pipelined/serial runs above.
    let mut recovery = None;
    let dropped = sc.drop_node.or(cluster.faults.dropout.map(|d| d.node));
    if let Some(node) = dropped {
        let replanned = plan.replan_without(node)?;
        let mut rex = Executor::with_config(&replanned, cfg.clone())?;
        let rr = rex.run_batch(&mut be, job.seed)?;
        if !rr.verified {
            return Err(HetcdcError::Backend(format!(
                "scenario {}: recovery plan failed oracle verification",
                sc.name
            )));
        }
        let makespan_s = rex.net_report().elapsed_s;
        recovery = Some(RecoveryStats {
            dropped_node: Some(node),
            payload_bytes: rr.payload_bytes,
            wire_bytes: rr.wire_bytes,
            rounds: replanned.shuffle.round_count() as u64,
            makespan_s,
            delta_payload_bytes: rr.payload_bytes as f64 - r_serial.payload_bytes as f64,
            delta_rounds: replanned.shuffle.round_count() as f64
                - plan.shuffle.round_count() as f64,
            delta_makespan_s: makespan_s - serial.net_report().elapsed_s,
            erased_broadcasts: None,
            retransmit_rounds: None,
            recovery_bytes: None,
        });
    }

    // Runtime-erasure recovery: the runs above already executed under
    // the erasure mask (and run_batch verified bit-identity against the
    // oracle). Record the serial run's recovery counters plus the
    // schedule cost of recovery — the makespan delta against an
    // erase-stripped twin executing the identical plan.
    if cluster.faults.erase.is_some() {
        let mut stripped = cluster.faults.clone();
        stripped.erase = None;
        let mut cex =
            Executor::with_config(&plan, cfg.clone().faults(stripped))?;
        let cr = cex.run_batch(&mut be, job.seed)?;
        if !cr.verified {
            return Err(HetcdcError::Backend(format!(
                "scenario {}: erase-stripped twin failed oracle verification",
                sc.name
            )));
        }
        let net = serial.net_report();
        let stats = recovery.get_or_insert(RecoveryStats {
            dropped_node: None,
            payload_bytes: r_serial.payload_bytes,
            wire_bytes: r_serial.wire_bytes,
            rounds: plan.shuffle.round_count() as u64,
            makespan_s: net.elapsed_s,
            delta_payload_bytes: r_serial.payload_bytes as f64 - cr.payload_bytes as f64,
            delta_rounds: 0.0,
            delta_makespan_s: net.elapsed_s - cex.net_report().elapsed_s,
            erased_broadcasts: None,
            retransmit_rounds: None,
            recovery_bytes: None,
        });
        stats.erased_broadcasts = Some(net.erased_broadcasts);
        stats.retransmit_rounds = Some(net.retransmit_rounds);
        stats.recovery_bytes = Some(net.recovery_bytes);
    }

    let straggler_delay_s = cluster
        .faults
        .straggle
        .map(|_| serial.net_report().straggler_delay_s);

    Ok(ScenarioResult {
        name: sc.name.to_string(),
        k,
        n_files: job.n_files,
        workload: job.workload.as_str(),
        placer: plan.placer.clone(),
        coder: plan.coder.clone(),
        mode: sc.mode.as_str(),
        sp: plan.alloc.sp,
        messages: r_serial.messages,
        rounds: plan.shuffle.round_count() as u64,
        payload_bytes: r_serial.payload_bytes,
        wire_bytes: r_serial.wire_bytes,
        load_equations: r_serial.load_equations,
        map_time_s: r_serial.map_time_s,
        shuffle_time_s: r_serial.shuffle_time_s,
        makespan_s: serial.net_report().elapsed_s,
        modes_identical: true,
        plan_build: PlanBuildStats::of(&plan.shuffle),
        dropped_collections: plan.dropped_collections.iter().map(|&(_, d)| d as u64).sum(),
        lp_solver: plan.lp_stats,
        straggler_delay_s,
        recovery,
        wall,
        wall_pipelined,
    })
}

/// The full suite's results plus totals — serializes to the
/// `BENCH_shuffle.json` artifact.
#[derive(Clone, Debug)]
pub struct SuiteReport {
    pub results: Vec<ScenarioResult>,
}

impl SuiteReport {
    /// Look up a scenario by name. Returns a typed error (not a panic)
    /// so a suite or baseline missing an expected scenario fails the
    /// gate with a message instead of aborting the process.
    pub fn scenario(&self, name: &str) -> Result<&ScenarioResult> {
        self.results.iter().find(|r| r.name == name).ok_or_else(|| {
            HetcdcError::InvalidParams(format!(
                "bench suite: scenario '{name}' missing (have: {})",
                self.results
                    .iter()
                    .map(|r| r.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })
    }

    pub fn total_payload_bytes(&self) -> u64 {
        self.results.iter().map(|r| r.payload_bytes).sum()
    }

    pub fn total_wire_bytes(&self) -> u64 {
        self.results.iter().map(|r| r.wire_bytes).sum()
    }

    pub fn total_messages(&self) -> u64 {
        self.results.iter().map(|r| r.messages).sum()
    }

    /// The artifact: no timestamps, no host info, no thread counts — the
    /// deterministic fields only, so identical code emits identical bytes
    /// (the `wall` blocks appear only under `--timing`).
    pub fn to_json(&self) -> Json {
        let mut totals = BTreeMap::new();
        totals.insert("payload_bytes".into(), Json::Num(self.total_payload_bytes() as f64));
        totals.insert("wire_bytes".into(), Json::Num(self.total_wire_bytes() as f64));
        totals.insert("messages".into(), Json::Num(self.total_messages() as f64));
        let mut m = BTreeMap::new();
        m.insert("schema".into(), Json::Num(SCHEMA_VERSION as f64));
        m.insert("suite".into(), Json::Str("shuffle".into()));
        m.insert(
            "scenarios".into(),
            Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
        );
        m.insert("totals".into(), Json::Obj(totals));
        Json::Obj(m)
    }
}

/// Run the whole [`default_suite`].
pub fn run_suite(threads: usize, timing: Option<&Bench>) -> Result<SuiteReport> {
    run_suite_with(threads, timing, None, None)
}

/// [`run_suite`] with optional topology and fault-spec overrides applied
/// to every scenario (the `bench-json --topology` / `--faults`
/// exploration paths). Overridden artifacts are *not* comparable to the
/// committed fault-free shared-medium baseline — the CLI skips the gate
/// when an override is active.
pub fn run_suite_with(
    threads: usize,
    timing: Option<&Bench>,
    topology: Option<Topology>,
    faults: Option<FaultSpec>,
) -> Result<SuiteReport> {
    run_scenarios(default_suite(), threads, timing, topology, faults)
}

/// [`run_suite_with`] over the [`extended_suite`] — the `bench-json`
/// path, which runs in release builds where the large-K exact-LP
/// masters solve in seconds.
pub fn run_extended_suite_with(
    threads: usize,
    timing: Option<&Bench>,
    topology: Option<Topology>,
    faults: Option<FaultSpec>,
) -> Result<SuiteReport> {
    run_scenarios(extended_suite(), threads, timing, topology, faults)
}

fn run_scenarios(
    scenarios: Vec<Scenario>,
    threads: usize,
    timing: Option<&Bench>,
    topology: Option<Topology>,
    faults: Option<FaultSpec>,
) -> Result<SuiteReport> {
    let mut results = Vec::new();
    for sc in scenarios {
        let mut sc = sc;
        if let Some(t) = topology {
            sc.topology = t;
        }
        if let Some(f) = &faults {
            sc.faults = f.clone();
        }
        results.push(run_scenario(&sc, threads, timing)?);
    }
    Ok(SuiteReport { results })
}

/// Verdict of a baseline comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineStatus {
    /// Within tolerance (possibly with informational notes).
    Pass,
    /// Baseline not yet blessed (missing/empty scenario list): the gate
    /// is disarmed; commit a generated artifact to arm it.
    Pending,
    /// Shuffle bytes regressed beyond tolerance, or scenario coverage
    /// was lost.
    Regression,
}

#[derive(Clone, Debug)]
pub struct Comparison {
    pub status: BaselineStatus,
    pub notes: Vec<String>,
}

fn num_at(j: &Json, path: &[&str]) -> Option<f64> {
    let mut cur = j;
    for p in path {
        cur = cur.get(p)?;
    }
    cur.as_f64()
}

/// Compare a freshly generated suite artifact against a committed
/// baseline. The gate: total payload bytes and total wire bytes may not
/// exceed the baseline by more than `tolerance_pct`; every baseline
/// scenario must still exist, none of them may individually regress
/// beyond tolerance, and each scenario's shuffle **round count** must
/// match the baseline exactly — an IR regression (e.g. a coder silently
/// collapsing its multi-round schedule into one giant round) changes the
/// round count even when the byte totals survive, and must fail loudly.
/// Improvements and new scenarios are notes, not failures (re-bless the
/// baseline to tighten the gate).
pub fn compare_to_baseline(current: &Json, baseline: &Json, tolerance_pct: f64) -> Comparison {
    let mut notes = Vec::new();
    let mut status = BaselineStatus::Pass;
    let empty: &[Json] = &[];
    // Only a literal `"scenarios": []` is the deliberate pending marker.
    // A missing or wrong-typed key is a broken baseline and must FAIL —
    // treating it as pending would silently disarm the gate.
    let base_scenarios = match baseline.get("scenarios").map(|s| s.as_arr()) {
        Some(Some(arr)) if arr.is_empty() => {
            return Comparison {
                status: BaselineStatus::Pending,
                notes: vec![
                    "baseline has no scenarios (pending): commit a generated \
                     BENCH_shuffle.json to arm the regression gate"
                        .into(),
                ],
            };
        }
        Some(Some(arr)) => arr,
        _ => {
            return Comparison {
                status: BaselineStatus::Regression,
                notes: vec![
                    "baseline is malformed: 'scenarios' is missing or not an array — \
                     fix it or re-bless a generated artifact"
                        .into(),
                ],
            };
        }
    };
    let tol = tolerance_pct / 100.0;

    for metric in ["payload_bytes", "wire_bytes"] {
        let cur = num_at(current, &["totals", metric]).unwrap_or(f64::NAN);
        let base = num_at(baseline, &["totals", metric]).unwrap_or(f64::NAN);
        if !cur.is_finite() || !base.is_finite() || base <= 0.0 {
            notes.push(format!("total {metric}: missing or invalid in artifact/baseline"));
            status = BaselineStatus::Regression;
            continue;
        }
        let ratio = cur / base;
        if ratio > 1.0 + tol {
            notes.push(format!(
                "total {metric} regressed {:+.2}% ({base:.0} -> {cur:.0}, tolerance {tolerance_pct}%)",
                100.0 * (ratio - 1.0)
            ));
            status = BaselineStatus::Regression;
        } else if ratio < 1.0 - tol {
            notes.push(format!(
                "total {metric} improved {:.2}% ({base:.0} -> {cur:.0}): consider re-blessing the baseline",
                100.0 * (1.0 - ratio)
            ));
        }
    }

    let cur_scenarios = current.get("scenarios").and_then(|s| s.as_arr()).unwrap_or(empty);
    /// Per-scenario gate inputs pulled out of one artifact entry.
    /// `Option` fields distinguish "not recorded" (legacy artifacts —
    /// the gate skips) from a recorded value; `dropped` is omitted in
    /// the artifact when 0.
    #[derive(Clone, Copy)]
    struct GateInputs {
        payload: f64,
        rounds: Option<f64>,
        makespan: Option<f64>,
        dropped: f64,
        /// Runtime-erasure recovery counters (`recovery.retransmit_rounds`
        /// / `recovery.recovery_bytes`) — recorded only by erase
        /// scenarios of post-erasure artifacts.
        retransmit_rounds: Option<f64>,
        recovery_bytes: Option<f64>,
    }
    fn by_name(list: &[Json]) -> BTreeMap<String, GateInputs> {
        list.iter()
            .filter_map(|s| {
                let recovery = s.get("recovery");
                Some((
                    s.get("name")?.as_str()?.to_string(),
                    GateInputs {
                        payload: s.get("payload_bytes")?.as_f64()?,
                        rounds: s.get("rounds").and_then(|r| r.as_f64()),
                        makespan: s.get("makespan_s").and_then(|r| r.as_f64()),
                        dropped: s.get("dropped_collections").and_then(|r| r.as_f64()).unwrap_or(0.0),
                        retransmit_rounds: recovery
                            .and_then(|r| r.get("retransmit_rounds"))
                            .and_then(|v| v.as_f64()),
                        recovery_bytes: recovery
                            .and_then(|r| r.get("recovery_bytes"))
                            .and_then(|v| v.as_f64()),
                    },
                ))
            })
            .collect()
    }
    let cur_map = by_name(cur_scenarios);
    let base_map = by_name(base_scenarios);
    for (name, base_in) in &base_map {
        let (base_payload, base_rounds, base_makespan, base_dropped) =
            (&base_in.payload, &base_in.rounds, &base_in.makespan, &base_in.dropped);
        match cur_map.get(name) {
            None => {
                notes.push(format!("scenario '{name}' disappeared (coverage lost)"));
                status = BaselineStatus::Regression;
            }
            Some(cur_in) => {
                let (cur_payload, cur_rounds, cur_makespan, cur_dropped) =
                    (&cur_in.payload, &cur_in.rounds, &cur_in.makespan, &cur_in.dropped);
                if *base_payload > 0.0 {
                    let ratio = cur_payload / base_payload;
                    if ratio > 1.0 + tol {
                        notes.push(format!(
                            "scenario '{name}' payload regressed {:+.2}% ({base_payload:.0} -> {cur_payload:.0})",
                            100.0 * (ratio - 1.0)
                        ));
                        status = BaselineStatus::Regression;
                    }
                }
                // Round-count drift is exact: the IR is deterministic, so
                // any change is a structural coder change — re-bless
                // deliberately or fix the regression. The skip is
                // asymmetric: a baseline predating the rounds field
                // records none (skip), but a *current* artifact missing
                // rounds that the baseline does record means the gate
                // itself lost its input — that must fail, not disarm.
                match (base_rounds, cur_rounds) {
                    (Some(b), Some(c)) if b != c => {
                        notes.push(format!(
                            "scenario '{name}' shuffle round count changed {b:.0} -> {c:.0} \
                             (IR regression or deliberate coder change: re-bless if intended)"
                        ));
                        status = BaselineStatus::Regression;
                    }
                    (Some(b), None) => {
                        notes.push(format!(
                            "scenario '{name}' no longer records its shuffle round count \
                             (baseline has {b:.0}): the IR gate lost its input"
                        ));
                        status = BaselineStatus::Regression;
                    }
                    _ => {}
                }
                // Schedule-length drift, tolerance-checked like bytes.
                // Same asymmetric skip as rounds: a pre-topology baseline
                // without makespan_s skips the check, but a current
                // artifact dropping the field the baseline records means
                // the schedule gate lost its input.
                match (base_makespan, cur_makespan) {
                    (Some(b), Some(c)) if *b > 0.0 && c / b > 1.0 + tol => {
                        notes.push(format!(
                            "scenario '{name}' shuffle makespan regressed {:+.2}% \
                             ({b:.6}s -> {c:.6}s, tolerance {tolerance_pct}%)",
                            100.0 * (c / b - 1.0)
                        ));
                        status = BaselineStatus::Regression;
                    }
                    (Some(b), Some(c)) if *b > 0.0 && c / b < 1.0 - tol => {
                        notes.push(format!(
                            "scenario '{name}' shuffle makespan improved {:.2}% \
                             ({b:.6}s -> {c:.6}s): consider re-blessing the baseline",
                            100.0 * (1.0 - c / b)
                        ));
                    }
                    (Some(b), None) => {
                        notes.push(format!(
                            "scenario '{name}' no longer records its shuffle makespan \
                             (baseline has {b:.6}s): the schedule gate lost its input"
                        ));
                        status = BaselineStatus::Regression;
                    }
                    _ => {}
                }
                // Dropped-collection drift is exact and asymmetric by
                // construction: the field is omitted when 0 on both
                // sides, so a legacy baseline reads as 0 and a scenario
                // that starts truncating (regressing from an exact,
                // cap-free placement) fails loudly. Dropping *fewer*
                // collections is an improvement note.
                if cur_dropped > base_dropped {
                    notes.push(format!(
                        "scenario '{name}' dropped_collections regressed \
                         {base_dropped:.0} -> {cur_dropped:.0}: the placement lost \
                         exactness (enumeration cap truncated)"
                    ));
                    status = BaselineStatus::Regression;
                } else if cur_dropped < base_dropped {
                    notes.push(format!(
                        "scenario '{name}' dropped_collections improved \
                         {base_dropped:.0} -> {cur_dropped:.0}: consider re-blessing \
                         the baseline"
                    ));
                }
                // Runtime-recovery counters, gated with the same
                // asymmetric legacy skip as rounds: a baseline predating
                // the erasure fields skips the check, but a current
                // artifact dropping a counter the baseline records means
                // the recovery gate lost its input. Retransmit rounds are
                // exact (deterministic protocol — any drift is a recovery
                // regression or a deliberate change); recovery bytes get
                // the byte tolerance.
                match (&base_in.retransmit_rounds, &cur_in.retransmit_rounds) {
                    (Some(b), Some(c)) if b != c => {
                        notes.push(format!(
                            "scenario '{name}' recovery retransmit_rounds changed \
                             {b:.0} -> {c:.0} (recovery-protocol change: re-bless if \
                             intended)"
                        ));
                        status = BaselineStatus::Regression;
                    }
                    (Some(b), None) => {
                        notes.push(format!(
                            "scenario '{name}' no longer records recovery \
                             retransmit_rounds (baseline has {b:.0}): the recovery \
                             gate lost its input"
                        ));
                        status = BaselineStatus::Regression;
                    }
                    _ => {}
                }
                match (&base_in.recovery_bytes, &cur_in.recovery_bytes) {
                    (Some(b), Some(c)) if *c > b * (1.0 + tol) => {
                        notes.push(format!(
                            "scenario '{name}' recovery bytes regressed \
                             {b:.0} -> {c:.0} (tolerance {tolerance_pct}%)"
                        ));
                        status = BaselineStatus::Regression;
                    }
                    (Some(b), Some(c)) if *b > 0.0 && *c < b * (1.0 - tol) => {
                        notes.push(format!(
                            "scenario '{name}' recovery bytes improved \
                             {b:.0} -> {c:.0}: consider re-blessing the baseline"
                        ));
                    }
                    (Some(b), None) => {
                        notes.push(format!(
                            "scenario '{name}' no longer records recovery bytes \
                             (baseline has {b:.0}): the recovery gate lost its input"
                        ));
                        status = BaselineStatus::Regression;
                    }
                    _ => {}
                }
            }
        }
    }
    for name in cur_map.keys() {
        if !base_map.contains_key(name) {
            notes.push(format!("scenario '{name}' is new (not in baseline)"));
        }
    }

    Comparison { status, notes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::OnceLock;

    /// One full-suite execution shared by every test in this module —
    /// the suite now spans K up to 16, so re-running it per test would
    /// dominate `cargo test` time.
    fn shared_report() -> &'static SuiteReport {
        static REPORT: OnceLock<SuiteReport> = OnceLock::new();
        REPORT.get_or_init(|| run_suite(2, None).expect("bench suite runs"))
    }

    #[test]
    fn suite_is_deterministic_across_runs_and_thread_counts() {
        let a = shared_report().to_json().to_string_pretty();
        let b = run_suite(4, None).unwrap().to_json().to_string_pretty();
        assert_eq!(a, b, "suite artifact must not depend on run or thread count");
    }

    #[test]
    fn artifact_records_plan_build_shape() {
        // Every scenario carries a timestamp-free plan_build section whose
        // rounds agree with the gated top-level rounds field.
        let j = shared_report().to_json();
        for sc in j.get("scenarios").unwrap().as_arr().unwrap() {
            let name = sc.get("name").and_then(|n| n.as_str()).unwrap();
            let pb = sc.get("plan_build").unwrap_or_else(|| {
                panic!("{name}: missing plan_build section")
            });
            for field in ["rounds", "groups", "broadcasts"] {
                let v = pb.get(field).and_then(|v| v.as_f64());
                assert!(v.unwrap_or(0.0) >= 1.0, "{name}: plan_build.{field} = {v:?}");
            }
            assert_eq!(
                pb.get("rounds").and_then(|v| v.as_f64()),
                sc.get("rounds").and_then(|v| v.as_f64()),
                "{name}: plan_build.rounds must mirror the gated rounds field"
            );
        }
    }

    #[test]
    fn coded_beats_uncoded_in_every_cluster() -> Result<()> {
        let report = shared_report();
        for k in ["k3", "k5", "k8"] {
            let coded = report.scenario(&format!("{k}-terasort-coded"))?;
            let uncoded = report.scenario(&format!("{k}-terasort-uncoded"))?;
            assert!(
                coded.payload_bytes < uncoded.payload_bytes,
                "{k}: coded {} >= uncoded {}",
                coded.payload_bytes,
                uncoded.payload_bytes
            );
        }
        Ok(())
    }

    #[test]
    fn combinatorial_beats_greedy_pairing_on_the_same_grid() -> Result<()> {
        // The acceptance gate of the grid design: measured shuffle bytes
        // of the combinatorial coder beat greedy pairing on the identical
        // K=8 placement (gain 3 vs at most 2).
        let report = shared_report();
        let comb = report.scenario("k8-terasort-combinatorial")?;
        let greedy = report.scenario("k8-terasort-grid-greedy")?;
        assert_eq!(comb.placer, "combinatorial");
        assert_eq!(comb.coder, "combinatorial");
        assert_eq!(greedy.coder, "greedy");
        assert!(
            comb.payload_bytes < greedy.payload_bytes,
            "combinatorial {} >= greedy {}",
            comb.payload_bytes,
            greedy.payload_bytes
        );
        // Multi-round IR reaches the larger-K scenarios too.
        for name in ["k12-terasort-combinatorial", "k16-terasort-combinatorial"] {
            let sc = report.scenario(name)?;
            assert_eq!(sc.coder, "combinatorial");
            assert!(sc.rounds > 1, "{name}: expected a multi-round plan");
        }
        Ok(())
    }

    #[test]
    fn rack_topology_cuts_makespan_at_unchanged_load() -> Result<()> {
        // The topology acceptance gate: each `-rack` scenario moves the
        // exact same bytes/messages/rounds as its shared-medium sibling
        // (the topology never changes what is sent), but finishes the
        // shuffle strictly sooner because the combinatorial coder's q
        // node-disjoint transversal groups per round run concurrently on
        // disjoint access links.
        let report = shared_report();
        for k in ["k8", "k12", "k16"] {
            let shared = report.scenario(&format!("{k}-terasort-combinatorial"))?;
            let rack = report.scenario(&format!("{k}-terasort-combinatorial-rack"))?;
            assert_eq!(rack.payload_bytes, shared.payload_bytes, "{k}: payload drift");
            assert_eq!(rack.wire_bytes, shared.wire_bytes, "{k}: wire drift");
            assert_eq!(rack.messages, shared.messages, "{k}: message drift");
            assert_eq!(rack.rounds, shared.rounds, "{k}: round drift");
            assert!(
                rack.makespan_s < shared.makespan_s,
                "{k}: rack makespan {} >= shared {}",
                rack.makespan_s,
                shared.makespan_s
            );
            // On the shared medium the schedule *is* the serialized fold.
            assert_eq!(shared.makespan_s.to_bits(), shared.shuffle_time_s.to_bits());
        }
        Ok(())
    }

    #[test]
    fn suite_topology_override_keeps_bytes_and_rounds() {
        // `bench-json --topology` path: overriding every scenario onto a
        // rack fabric must leave all deterministic byte/round metrics
        // identical to the default suite — only schedules change.
        let over = run_suite_with(2, None, Some(Topology::Rack { racks: 1, oversub: 2.0 }), None)
            .expect("override suite runs");
        let base = shared_report();
        for (o, b) in over.results.iter().zip(&base.results) {
            assert_eq!(o.name, b.name);
            assert_eq!(o.payload_bytes, b.payload_bytes, "{}", o.name);
            assert_eq!(o.wire_bytes, b.wire_bytes, "{}", o.name);
            assert_eq!(o.messages, b.messages, "{}", o.name);
            assert_eq!(o.rounds, b.rounds, "{}", o.name);
        }
    }

    #[test]
    fn straggle_twin_keeps_bytes_and_stretches_schedule() -> Result<()> {
        // The straggler acceptance gate: the `-straggle` twin moves the
        // exact same bytes/messages/rounds as the fault-free scenario
        // (jitter never changes what is sent), its nominal Map barrier is
        // unchanged, and all the slowdown shows up as schedule waits.
        let report = shared_report();
        let clean = report.scenario("k8-terasort-combinatorial")?;
        let strag = report.scenario("k8-terasort-combinatorial-straggle")?;
        assert_eq!(strag.payload_bytes, clean.payload_bytes);
        assert_eq!(strag.wire_bytes, clean.wire_bytes);
        assert_eq!(strag.messages, clean.messages);
        assert_eq!(strag.rounds, clean.rounds);
        assert_eq!(strag.map_time_s.to_bits(), clean.map_time_s.to_bits());
        let delay = strag.straggler_delay_s.expect("straggle scenario records its delay");
        assert!(delay > 0.0, "expected a positive straggler delay");
        assert!(
            strag.shuffle_time_s > clean.shuffle_time_s,
            "straggle shuffle {} <= clean {}",
            strag.shuffle_time_s,
            clean.shuffle_time_s
        );
        assert!(clean.straggler_delay_s.is_none());
        Ok(())
    }

    #[test]
    fn repair_twin_pays_a_measured_loss_tolerance_price() -> Result<()> {
        // Degraded decode is not free: the f=1 twin's plan carries extra
        // verified repair rounds, and the artifact records their cost.
        let report = shared_report();
        let clean = report.scenario("k8-terasort-combinatorial")?;
        let rep = report.scenario("k8-terasort-combinatorial-repair1")?;
        assert!(rep.rounds > clean.rounds, "{} vs {}", rep.rounds, clean.rounds);
        assert!(rep.wire_bytes > clean.wire_bytes);
        assert!(rep.payload_bytes > clean.payload_bytes);
        assert!(rep.straggler_delay_s.is_none(), "repair alone adds no jitter");
        Ok(())
    }

    #[test]
    fn dropout_scenario_records_recovery_cost() -> Result<()> {
        let report = shared_report();
        let drop = report.scenario("k8-terasort-dropout")?;
        let rec = drop.recovery.expect("dropout scenario records recovery stats");
        assert_eq!(rec.dropped_node, Some(0));
        assert!(rec.retransmit_rounds.is_none(), "dropout-only record has no erase counters");
        assert!(rec.payload_bytes > 0);
        assert!(rec.rounds >= 1);
        assert!(rec.makespan_s > 0.0);
        assert_eq!(
            rec.delta_payload_bytes,
            rec.payload_bytes as f64 - drop.payload_bytes as f64
        );
        assert_eq!(rec.delta_makespan_s, rec.makespan_s - drop.makespan_s);
        // Fault-free scenarios record no recovery section.
        assert!(report.scenario("k8-terasort-combinatorial")?.recovery.is_none());
        Ok(())
    }

    #[test]
    fn erasure_twin_records_runtime_recovery() -> Result<()> {
        let report = shared_report();
        let er = report.scenario("k8-terasort-combinatorial-erase")?;
        let rec = er.recovery.expect("erase scenario records recovery stats");
        assert!(rec.dropped_node.is_none(), "erasure-only record has no dropped node");
        // The recorded erased count must equal what the committed seed/p
        // deterministically erases on this plan's coordinates at epoch 1
        // (the first batch of a fresh executor) — the artifact is a pure
        // function of the spec, never of run order or thread count.
        let row = default_suite()
            .into_iter()
            .find(|s| s.name == "k8-terasort-combinatorial-erase")
            .expect("suite has the erase twin");
        let cluster = row.cluster();
        let erase = cluster.faults.erase.clone().expect("erase twin has an erase clause");
        let plan = JobBuilder::new(&cluster, &row.job())
            .placer(row.placer)
            .mode(row.mode)
            .build()?;
        let expected = plan
            .shuffle
            .coords()
            .iter()
            .filter(|&&(r, g, b)| erase.erased(1, r, g, b))
            .count() as u64;
        assert_eq!(rec.erased_broadcasts, Some(expected));
        // Erasures never change what the planned schedule sends: the
        // faulted run's plan metrics equal the erase-stripped twin's.
        assert_eq!(rec.payload_bytes, er.payload_bytes);
        assert_eq!(rec.delta_payload_bytes, 0.0);
        assert_eq!(rec.delta_rounds, 0.0);
        // With f=1 repair rounds, single per-group losses are absorbed at
        // decode time for free; anything beyond tolerance is recovered by
        // retransmission rounds metered on top of the schedule.
        let retx = rec.retransmit_rounds.expect("erase scenario records retransmit rounds");
        let bytes = rec.recovery_bytes.expect("erase scenario records recovery bytes");
        if retx == 0 {
            assert_eq!(bytes, 0);
            assert_eq!(rec.delta_makespan_s, 0.0, "absorbed losses cost no schedule time");
        } else {
            assert!(bytes > 0);
            assert!(rec.delta_makespan_s > 0.0, "retransmissions must cost schedule time");
        }
        Ok(())
    }

    #[test]
    fn midrun_dropout_scenario_switches_to_the_survivor_plan() -> Result<()> {
        let report = shared_report();
        let sc = report.scenario("k8-terasort-midrun-dropout")?;
        let rec = sc.recovery.expect("mid-run dropout records recovery stats");
        assert_eq!(rec.dropped_node, Some(0));
        assert!(rec.retransmit_rounds.is_none(), "dropout-only record has no erase counters");
        // The scenario's multi-batch runs actually switch over: batches
        // before `at_batch` execute the original plan, the rest are
        // stamped with the survivor re-plan.
        let row = default_suite()
            .into_iter()
            .find(|s| s.name == "k8-terasort-midrun-dropout")
            .expect("suite has the mid-run dropout twin");
        let cluster = row.cluster();
        let d = cluster.faults.dropout.expect("mid-run twin has a drop clause");
        let job = row.job();
        let plan = JobBuilder::new(&cluster, &job)
            .placer(row.placer)
            .mode(row.mode)
            .build()?;
        let mut be = NativeBackend;
        let mut ex = Executor::with_config(&plan, ExecConfig::default().threads(2))?;
        let seeds: Vec<u64> =
            (0..PIPELINE_BATCHES).map(|b| job.seed.wrapping_add(b)).collect();
        let reports = ex.run_batches(&mut be, &seeds)?;
        assert_eq!(reports.len(), seeds.len());
        for (i, r) in reports.iter().enumerate() {
            assert!(r.verified, "batch {i} failed verification");
            assert_eq!(
                r.replanned_without,
                (i as u64 >= d.at_batch).then_some(d.node),
                "batch {i}: switchover stamp"
            );
        }
        Ok(())
    }

    #[test]
    fn fault_free_scenarios_serialize_without_fault_keys() {
        // Backward-compat contract of the artifact: fault fields appear
        // only on scenarios that configured the corresponding fault, the
        // lp_solver block only on exact-LP scenarios, and
        // dropped_collections never on a cap-free suite.
        let j = shared_report().to_json();
        for sc in j.get("scenarios").unwrap().as_arr().unwrap() {
            let name = sc.get("name").and_then(|n| n.as_str()).unwrap();
            assert_eq!(
                sc.get("straggler_delay_s").is_some(),
                name.contains("straggle"),
                "{name}: straggler_delay_s presence"
            );
            assert_eq!(
                sc.get("recovery").is_some(),
                name.contains("dropout") || name.contains("erase"),
                "{name}: recovery presence"
            );
            // Erasure counters live only inside erase-scenario recovery
            // blocks; dropout records stay byte-identical to pre-erasure
            // artifacts.
            assert_eq!(
                sc.get("recovery").and_then(|r| r.get("erased_broadcasts")).is_some(),
                name.contains("erase"),
                "{name}: recovery.erased_broadcasts presence"
            );
            let placer = sc.get("placer").and_then(|p| p.as_str()).unwrap();
            assert_eq!(
                sc.get("plan_build").and_then(|pb| pb.get("lp_solver")).is_some(),
                placer == "lp-general",
                "{name}: plan_build.lp_solver presence (placer {placer})"
            );
            assert!(
                sc.get("dropped_collections").is_none(),
                "{name}: cap-free suite must not drop collections"
            );
        }
    }

    #[test]
    fn exact_lp_scenario_records_certified_counters() -> Result<()> {
        // The perf claim of the exact path, measured in the committed
        // artifact: the revised simplex's factorized work
        // (eta_applications) is strictly below the dense-tableau
        // counterfactual over the same pivot walk, and the solve is
        // dual-certified with nothing dropped.
        let report = shared_report();
        let sc = report.scenario("k8-terasort-lp-exact")?;
        assert_eq!(sc.placer, "lp-general");
        assert_eq!(sc.dropped_collections, 0);
        let stats = sc.lp_solver.expect("exact-LP scenario records lp_solver");
        assert!(stats.certified, "K=8 must certify: {stats:?}");
        assert!(stats.pivots > 0);
        assert!(
            stats.eta_applications < stats.dense_cells,
            "revised simplex must beat the dense counterfactual: {stats:?}"
        );
        assert!(stats.z_exact > 0.0);
        // k5 routes through `auto` -> exact LP too.
        let k5 = report.scenario("k5-terasort-coded")?;
        assert!(k5.lp_solver.expect("k5 exact counters").certified);
        Ok(())
    }

    #[test]
    fn dropped_collections_regression_fails_the_gate() {
        let current = shared_report().to_json();
        // Baseline identical to current (both omit the field = 0): a
        // doctored CURRENT artifact that starts dropping collections
        // must regress — this is the "regressing from 0 fails" arm that
        // also covers legacy baselines predating the field.
        let mut doctored = current.clone();
        if let Json::Obj(m) = &mut doctored {
            if let Some(Json::Arr(sc)) = m.get_mut("scenarios") {
                if let Some(Json::Obj(first)) = sc.first_mut() {
                    first.insert("dropped_collections".into(), Json::Num(3.0));
                }
            }
        }
        let cmp = compare_to_baseline(&doctored, &current, 5.0);
        assert_eq!(cmp.status, BaselineStatus::Regression, "{:?}", cmp.notes);
        assert!(
            cmp.notes.iter().any(|n| n.contains("dropped_collections regressed")),
            "{:?}",
            cmp.notes
        );
        // The reverse direction (baseline dropped, current exact) is an
        // improvement note, not a failure.
        let cmp = compare_to_baseline(&current, &doctored, 5.0);
        assert_eq!(cmp.status, BaselineStatus::Pass, "{:?}", cmp.notes);
        assert!(
            cmp.notes.iter().any(|n| n.contains("dropped_collections improved")),
            "{:?}",
            cmp.notes
        );
    }

    #[test]
    fn suite_faults_override_keeps_bytes() {
        // `bench-json --faults` path: a straggle override stretches
        // schedules but leaves every byte/message/round metric identical.
        // Scenarios whose own spec includes repair are skipped — the
        // override *replaces* the spec, so their plans lose the repair
        // rounds by design (the erase twin carries repair:f=1 too).
        let f = FaultSpec::parse("straggle:seed=7,amp=2").unwrap();
        let over = run_suite_with(2, None, None, Some(f)).expect("override suite runs");
        let base = shared_report();
        for (o, b) in over.results.iter().zip(&base.results) {
            assert_eq!(o.name, b.name);
            if o.name.contains("repair") || o.name.contains("erase") {
                continue;
            }
            assert_eq!(o.payload_bytes, b.payload_bytes, "{}", o.name);
            assert_eq!(o.wire_bytes, b.wire_bytes, "{}", o.name);
            assert_eq!(o.messages, b.messages, "{}", o.name);
            assert_eq!(o.rounds, b.rounds, "{}", o.name);
            assert!(o.straggler_delay_s.is_some(), "{}", o.name);
        }
    }

    #[test]
    fn makespan_drift_fails_the_gate() {
        let current = shared_report().to_json();
        // Baseline whose first scenario finished 50% faster: the current
        // artifact "regressed" past any reasonable tolerance.
        let mut doctored = current.clone();
        if let Json::Obj(m) = &mut doctored {
            if let Some(Json::Arr(sc)) = m.get_mut("scenarios") {
                if let Some(Json::Obj(first)) = sc.first_mut() {
                    let ms = first.get("makespan_s").and_then(|r| r.as_f64()).unwrap();
                    first.insert("makespan_s".into(), Json::Num(ms * 0.5));
                }
            }
        }
        let cmp = compare_to_baseline(&current, &doctored, 5.0);
        assert_eq!(cmp.status, BaselineStatus::Regression, "{:?}", cmp.notes);
        assert!(
            cmp.notes.iter().any(|n| n.contains("makespan regressed")),
            "{:?}",
            cmp.notes
        );
        // A pre-topology baseline without makespan_s skips the check...
        let mut legacy = current.clone();
        if let Json::Obj(m) = &mut legacy {
            if let Some(Json::Arr(sc)) = m.get_mut("scenarios") {
                for s in sc.iter_mut() {
                    if let Json::Obj(obj) = s {
                        obj.remove("makespan_s");
                    }
                }
            }
        }
        let cmp = compare_to_baseline(&current, &legacy, 5.0);
        assert_eq!(cmp.status, BaselineStatus::Pass, "{:?}", cmp.notes);
        // ... but a current artifact dropping the field fails, same
        // asymmetry as the round-count gate.
        let cmp = compare_to_baseline(&legacy, &current, 5.0);
        assert_eq!(cmp.status, BaselineStatus::Regression, "{:?}", cmp.notes);
        assert!(
            cmp.notes.iter().any(|n| n.contains("schedule gate lost its input")),
            "{:?}",
            cmp.notes
        );
    }

    #[test]
    fn round_count_drift_fails_the_gate() {
        let current = shared_report().to_json();
        let mut doctored = current.clone();
        if let Json::Obj(m) = &mut doctored {
            if let Some(Json::Arr(sc)) = m.get_mut("scenarios") {
                if let Some(Json::Obj(first)) = sc.first_mut() {
                    let rounds = first.get("rounds").and_then(|r| r.as_f64()).unwrap();
                    first.insert("rounds".into(), Json::Num(rounds + 1.0));
                }
            }
        }
        let cmp = compare_to_baseline(&current, &doctored, 5.0);
        assert_eq!(cmp.status, BaselineStatus::Regression, "{:?}", cmp.notes);
        assert!(
            cmp.notes.iter().any(|n| n.contains("round count changed")),
            "{:?}",
            cmp.notes
        );
        // A baseline without the rounds field (pre-IR artifact) skips the
        // round check instead of failing spuriously.
        let mut legacy = current.clone();
        if let Json::Obj(m) = &mut legacy {
            if let Some(Json::Arr(sc)) = m.get_mut("scenarios") {
                for s in sc.iter_mut() {
                    if let Json::Obj(obj) = s {
                        obj.remove("rounds");
                    }
                }
            }
        }
        let cmp = compare_to_baseline(&current, &legacy, 5.0);
        assert_eq!(cmp.status, BaselineStatus::Pass, "{:?}", cmp.notes);
        // ... but the skip is asymmetric: a CURRENT artifact that stops
        // recording rounds against a baseline that has them means the
        // gate lost its input — regression, never a silent disarm.
        let cmp = compare_to_baseline(&legacy, &current, 5.0);
        assert_eq!(cmp.status, BaselineStatus::Regression, "{:?}", cmp.notes);
        assert!(
            cmp.notes.iter().any(|n| n.contains("lost its input")),
            "{:?}",
            cmp.notes
        );
    }

    #[test]
    fn scenario_lookup_is_typed_not_panicking() {
        let report = SuiteReport { results: Vec::new() };
        let err = report.scenario("k3-terasort-coded").unwrap_err();
        assert!(
            matches!(err, HetcdcError::InvalidParams(_)),
            "expected typed lookup failure, got {err:?}"
        );
        assert!(err.to_string().contains("k3-terasort-coded"));
    }

    #[test]
    fn self_comparison_passes_and_regressions_fail() {
        let current = shared_report().to_json();
        let same = compare_to_baseline(&current, &current, 5.0);
        assert_eq!(same.status, BaselineStatus::Pass, "{:?}", same.notes);

        // Shrink the baseline totals by 10%: current "regresses" past 5%.
        let mut doctored = current.clone();
        if let Json::Obj(m) = &mut doctored {
            let mut totals = BTreeMap::new();
            for metric in ["payload_bytes", "wire_bytes", "messages"] {
                let v = num_at(&current, &["totals", metric]).unwrap();
                totals.insert(metric.to_string(), Json::Num((v * 0.9).floor()));
            }
            m.insert("totals".into(), Json::Obj(totals));
        }
        let worse = compare_to_baseline(&current, &doctored, 5.0);
        assert_eq!(worse.status, BaselineStatus::Regression, "{:?}", worse.notes);
    }

    #[test]
    fn pending_baseline_disarms_the_gate() {
        let current = shared_report().to_json();
        let pending = Json::parse(r#"{"schema": 1, "scenarios": []}"#).unwrap();
        assert_eq!(
            compare_to_baseline(&current, &pending, 5.0).status,
            BaselineStatus::Pending
        );
        // A baseline with a missing or wrong-typed 'scenarios' is broken,
        // not pending: the gate must fail loudly instead of disarming.
        for malformed in [r#"{"schema": 1}"#, r#"{"scenarios": {"oops": 1}}"#] {
            let j = Json::parse(malformed).unwrap();
            assert_eq!(
                compare_to_baseline(&current, &j, 5.0).status,
                BaselineStatus::Regression,
                "{malformed}"
            );
        }
        // Lost coverage is a regression even when totals look fine.
        let mut one_less = current.clone();
        if let Json::Obj(m) = &mut one_less {
            if let Some(Json::Arr(sc)) = m.get_mut("scenarios") {
                sc.pop();
            }
        }
        assert_eq!(
            compare_to_baseline(&one_less, &current, 5.0).status,
            BaselineStatus::Regression
        );
    }
}
