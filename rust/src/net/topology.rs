//! Switched-network topology model: per-link rate tables.
//!
//! [`Topology`] describes what sits between the nodes' uplinks and the
//! rest of the cluster. `Shared` is the paper's §II single broadcast
//! medium (every transmission serializes — the model all previous
//! artifacts were produced under, preserved bit-for-bit). The switched
//! variants replace the one medium with a table of **links**:
//!
//! - `Flat` — a full-bisection switch: one access link per node, no
//!   shared trunk. Multicast groups with distinct senders never contend.
//! - `Rack { racks, oversub }` — nodes are blocked into `racks`
//!   top-of-rack switches; each rack owns an aggregation uplink whose
//!   rate is the sum of its members' access rates divided by
//!   `oversub` (the classic oversubscription ratio). A broadcast
//!   occupies its sender's access link, plus the sender's rack uplink
//!   when any destination lives outside the rack (sender-side egress:
//!   the switch replicates the multicast downstream, so destination
//!   racks' uplinks carry no copy upward).
//! - `FatTree { racks }` — the same structure at full bisection
//!   (`oversub = 1`): rack trunks exist and are metered, but are
//!   provisioned to never be slower than their members combined.
//!
//! Scheduling over these links lives in [`crate::net::sim`]; this module
//! only names, validates, and sizes the links.

use crate::error::{HetcdcError, Result};
use crate::util::json::Json;

fn invalid(msg: impl Into<String>) -> HetcdcError {
    HetcdcError::InvalidParams(msg.into())
}

/// Network topology of a cluster. Parsed from / rendered to the CLI
/// `--topology` spec string; `Shared` is the default everywhere and is
/// omitted from serialized cluster JSON so existing artifacts and
/// fingerprints are unchanged.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Topology {
    /// Single shared broadcast medium (§II): all transmissions serialize.
    Shared,
    /// Full-bisection switch: per-node access links only.
    Flat,
    /// `racks` top-of-rack switches behind oversubscribed uplinks.
    Rack { racks: usize, oversub: f64 },
    /// Rack structure at full bisection (`oversub = 1`).
    FatTree { racks: usize },
}

impl Default for Topology {
    fn default() -> Self {
        Topology::Shared
    }
}

impl Topology {
    /// Parse a CLI/JSON spec string. Accepted forms:
    /// `shared` | `flat` | `rack:q=R,oversub=X` | `fat-tree:q=R`
    /// (`racks=` and `oversubscription=` are accepted aliases).
    pub fn parse(spec: &str) -> Result<Topology> {
        let spec = spec.trim();
        match spec {
            "shared" => return Ok(Topology::Shared),
            "flat" => return Ok(Topology::Flat),
            _ => {}
        }
        let (head, body) = spec
            .split_once(':')
            .ok_or_else(|| invalid(format!("unknown topology '{spec}'")))?;
        let mut racks: Option<usize> = None;
        let mut oversub: Option<f64> = None;
        for pair in body.split(',').filter(|p| !p.trim().is_empty()) {
            let (key, val) = pair
                .split_once('=')
                .ok_or_else(|| invalid(format!("topology option '{pair}' is not key=value")))?;
            match (key.trim(), val.trim()) {
                ("q" | "racks", v) => {
                    racks = Some(v.parse::<usize>().map_err(|_| {
                        invalid(format!("topology rack count '{v}' is not an integer"))
                    })?);
                }
                ("oversub" | "oversubscription", v) => {
                    oversub = Some(v.parse::<f64>().map_err(|_| {
                        invalid(format!("topology oversubscription '{v}' is not a number"))
                    })?);
                }
                (k, _) => return Err(invalid(format!("unknown topology option '{k}'"))),
            }
        }
        let racks =
            racks.ok_or_else(|| invalid(format!("topology '{head}' needs q=<racks>")))?;
        match head {
            "rack" => Ok(Topology::Rack {
                racks,
                oversub: oversub.unwrap_or(1.0),
            }),
            "fat-tree" | "fattree" => {
                if oversub.is_some() {
                    return Err(invalid(
                        "fat-tree is full-bisection; oversub is not accepted",
                    ));
                }
                Ok(Topology::FatTree { racks })
            }
            _ => Err(invalid(format!("unknown topology '{head}'"))),
        }
    }

    /// Canonical spec string: `parse(spec()) == self`, and equal
    /// topologies render equal strings (used in cache keys and
    /// fingerprints).
    pub fn spec(&self) -> String {
        match self {
            Topology::Shared => "shared".into(),
            Topology::Flat => "flat".into(),
            Topology::Rack { racks, oversub } => format!("rack:q={racks},oversub={oversub}"),
            Topology::FatTree { racks } => format!("fat-tree:q={racks}"),
        }
    }

    pub fn is_shared(&self) -> bool {
        matches!(self, Topology::Shared)
    }

    /// Oversubscription ratio of the rack trunks (1 when absent).
    pub fn oversub(&self) -> f64 {
        match self {
            Topology::Rack { oversub, .. } => *oversub,
            _ => 1.0,
        }
    }

    /// Validate the topology against a cluster of `k` nodes.
    pub fn validate(&self, k: usize) -> Result<()> {
        match *self {
            Topology::Shared | Topology::Flat => Ok(()),
            Topology::Rack { racks, oversub } => {
                check_racks(racks, k)?;
                if !(oversub.is_finite() && oversub > 0.0) {
                    return Err(invalid(format!(
                        "oversubscription must be positive and finite, got {oversub}"
                    )));
                }
                Ok(())
            }
            Topology::FatTree { racks } => check_racks(racks, k),
        }
    }

    /// Rack index of `node` in a `k`-node cluster (blocked assignment:
    /// contiguous node ranges map to consecutive racks).
    pub fn rack_of(&self, node: usize, k: usize) -> usize {
        match *self {
            Topology::Rack { racks, .. } | Topology::FatTree { racks } => node * racks / k,
            _ => 0,
        }
    }

    /// Build the per-link rate table for nodes with the given access
    /// rates (bits/s). `None` for the shared medium: it has no links,
    /// only the serialized clock.
    pub fn link_table(&self, uplink_bps: &[f64]) -> Result<Option<LinkTable>> {
        let k = uplink_bps.len();
        self.validate(k)?;
        let (racks, oversub) = match *self {
            Topology::Shared => return Ok(None),
            Topology::Flat => (0, 1.0),
            Topology::Rack { racks, oversub } => (racks, oversub),
            Topology::FatTree { racks } => (racks, 1.0),
        };
        let mut ids: Vec<String> = (0..k).map(|i| format!("node{i}")).collect();
        let mut rates_bps = uplink_bps.to_vec();
        let mut agg = vec![None; k];
        let mut rack_mask = vec![full_mask(k); k];
        if racks > 0 {
            let mut rack_sum = vec![0.0f64; racks];
            let mut masks = vec![0u32; racks];
            for node in 0..k {
                let r = self.rack_of(node, k);
                rack_sum[r] += uplink_bps[node];
                masks[r] |= 1u32 << node;
            }
            for (r, &sum) in rack_sum.iter().enumerate() {
                let rate = sum / oversub;
                if !(rate.is_finite() && rate > 0.0) {
                    return Err(invalid(format!(
                        "rack {r} uplink rate must be positive and finite, got {rate}"
                    )));
                }
                ids.push(format!("rack{r}"));
                rates_bps.push(rate);
            }
            for node in 0..k {
                let r = self.rack_of(node, k);
                agg[node] = Some(k + r);
                rack_mask[node] = masks[r];
            }
        }
        Ok(Some(LinkTable {
            ids,
            rates_bps,
            agg,
            rack_mask,
        }))
    }

    /// JSON form used inside serialized cluster specs (the spec string).
    pub fn to_json(&self) -> Json {
        Json::Str(self.spec())
    }

    pub fn from_json(j: &Json) -> Result<Topology> {
        j.as_str()
            .ok_or_else(|| HetcdcError::Json("topology must be a spec string".into()))
            .and_then(Topology::parse)
    }
}

fn check_racks(racks: usize, k: usize) -> Result<()> {
    if racks == 0 || (k > 0 && racks > k) {
        return Err(invalid(format!(
            "rack count {racks} out of range [1, {k}]"
        )));
    }
    Ok(())
}

fn full_mask(k: usize) -> u32 {
    if k >= 32 {
        u32::MAX
    } else {
        (1u32 << k) - 1
    }
}

/// Immutable per-link rate table of a switched topology. Links
/// `0..k` are the node access links; rack trunks (if any) follow.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkTable {
    /// Stable link names (`node{i}`, then `rack{r}`), the identity
    /// reported in [`crate::net::LinkLedger`].
    pub ids: Vec<String>,
    /// Link rates, bits/second, parallel to `ids`.
    pub rates_bps: Vec<f64>,
    /// Per node: the rack trunk its egress traffic rides (None on
    /// `Flat`, where there is no trunk).
    pub agg: Vec<Option<usize>>,
    /// Per node: bitmask of the nodes sharing its rack (the full node
    /// set on `Flat`). A broadcast whose destinations all fall inside
    /// this mask never leaves the rack.
    pub rack_mask: Vec<u32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_spec_roundtrip() {
        for spec in ["shared", "flat", "rack:q=3,oversub=4", "fat-tree:q=2"] {
            let t = Topology::parse(spec).unwrap();
            assert_eq!(t.spec(), spec);
            assert_eq!(Topology::parse(&t.spec()).unwrap(), t);
        }
        assert_eq!(
            Topology::parse("rack:racks=2,oversubscription=2.5").unwrap(),
            Topology::Rack { racks: 2, oversub: 2.5 }
        );
        assert_eq!(
            Topology::parse("rack:q=2").unwrap(),
            Topology::Rack { racks: 2, oversub: 1.0 }
        );
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        for bad in [
            "ring",
            "rack",
            "rack:oversub=2",
            "rack:q=two",
            "rack:q=2,flavor=hot",
            "fat-tree:q=2,oversub=3",
        ] {
            assert!(
                matches!(Topology::parse(bad), Err(HetcdcError::InvalidParams(_))),
                "{bad}"
            );
        }
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        assert!(Topology::Rack { racks: 0, oversub: 1.0 }.validate(4).is_err());
        assert!(Topology::Rack { racks: 5, oversub: 1.0 }.validate(4).is_err());
        assert!(Topology::Rack { racks: 2, oversub: 0.0 }.validate(4).is_err());
        assert!(Topology::Rack { racks: 2, oversub: -1.0 }.validate(4).is_err());
        assert!(Topology::Rack { racks: 2, oversub: f64::NAN }.validate(4).is_err());
        assert!(Topology::FatTree { racks: 9 }.validate(8).is_err());
        assert!(Topology::Rack { racks: 2, oversub: 4.0 }.validate(4).is_ok());
    }

    #[test]
    fn rack_assignment_is_blocked_and_total() {
        let t = Topology::Rack { racks: 3, oversub: 2.0 };
        let racks: Vec<usize> = (0..12).map(|n| t.rack_of(n, 12)).collect();
        assert_eq!(racks, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2]);
        // Non-dividing K still covers every rack monotonically.
        let racks: Vec<usize> = (0..5).map(|n| t.rack_of(n, 5)).collect();
        assert_eq!(racks, vec![0, 0, 1, 1, 2]);
    }

    #[test]
    fn link_table_sizes_trunks_from_member_rates() {
        let t = Topology::Rack { racks: 2, oversub: 4.0 };
        let lt = t.link_table(&[100.0, 200.0, 300.0, 400.0]).unwrap().unwrap();
        assert_eq!(lt.ids, vec!["node0", "node1", "node2", "node3", "rack0", "rack1"]);
        assert_eq!(lt.rates_bps[4], (100.0 + 200.0) / 4.0);
        assert_eq!(lt.rates_bps[5], (300.0 + 400.0) / 4.0);
        assert_eq!(lt.agg, vec![Some(4), Some(4), Some(5), Some(5)]);
        assert_eq!(lt.rack_mask, vec![0b0011, 0b0011, 0b1100, 0b1100]);
    }

    #[test]
    fn flat_has_access_links_only_and_shared_has_none() {
        let lt = Topology::Flat.link_table(&[1e6, 2e6]).unwrap().unwrap();
        assert_eq!(lt.ids, vec!["node0", "node1"]);
        assert_eq!(lt.agg, vec![None, None]);
        assert!(Topology::Shared.link_table(&[1e6]).unwrap().is_none());
    }

    #[test]
    fn fat_tree_is_full_bisection() {
        let lt = Topology::FatTree { racks: 2 }
            .link_table(&[1e6, 1e6, 1e6, 1e6])
            .unwrap()
            .unwrap();
        assert_eq!(lt.rates_bps[4], 2e6);
        assert_eq!(lt.rates_bps[5], 2e6);
    }

    #[test]
    fn json_roundtrip() {
        let t = Topology::Rack { racks: 3, oversub: 4.0 };
        assert_eq!(Topology::from_json(&t.to_json()).unwrap(), t);
        assert!(Topology::from_json(&Json::Num(3.0)).is_err());
    }
}
