//! Simulated broadcast network with heterogeneous uplinks.
//!
//! The paper's Shuffle phase is a sequence of broadcasts on a shared
//! medium (§II): each message from node `k` reaches all other nodes. The
//! simulator byte-accounts every broadcast exactly (this is the paper's
//! communication-load metric, measured rather than predicted) and advances
//! a virtual clock: node `k` transmits at `uplink_bps[k]`, transmissions
//! on the shared medium serialize, and each message pays a fixed `latency`
//! (the EC2-style per-message overhead that makes many small messages
//! slower than few large ones — why coded shuffle also wins wall-clock).
//!
//! Accounting lives in [`PhaseLedger`], a plain-data (`Send + Sync`)
//! record separate from the rate table, so the parallel executor can keep
//! the metering pass on one thread — in exact plan order, preserving the
//! bit-exact serialized-broadcast clock — while decode workers run
//! concurrently. The clock is a float fold over per-broadcast times;
//! float addition is not associative, so the ledger is never merged from
//! per-worker partials: every broadcast is recorded through the same
//! sequential [`BroadcastNet::broadcast`] path in both execution modes.
//!
//! This substitutes for the paper's EC2 testbed (DESIGN.md §4): the
//! load metric is exact; the time model preserves the who-wins ordering.

use crate::error::{HetcdcError, Result};

/// Byte/message/clock accounting of one shuffle *round* — one section of
/// a [`PhaseLedger`]. `elapsed_s` is the round's own sequential float
/// fold; the phase total is folded separately (float addition is not
/// associative, so the per-round sums are not re-added into the total).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundLedger {
    pub bytes: u64,
    pub msgs: u64,
    pub elapsed_s: f64,
}

/// Byte/message/clock accounting of one phase, separated from the rate
/// table so it can travel across threads (plain data, `Send + Sync`).
///
/// Records must be appended in broadcast order via [`PhaseLedger::record`]
/// — the clock is an order-sensitive float fold (see module docs). Round
/// boundaries ([`PhaseLedger::begin_round`]) segment the same sequential
/// pass into per-round sections; they never change the totals.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseLedger {
    bytes_by_node: Vec<u64>,
    msgs_by_node: Vec<u64>,
    clock_s: f64,
    /// Per-round sections of the current phase (the multi-round shuffle
    /// IR: the executor opens one section per [`ShuffleRound`]). Records
    /// arriving before any `begin_round` fall into an implicit first
    /// section, so round-less callers (ad-hoc benches, prediction of
    /// legacy plans) still account correctly.
    ///
    /// [`ShuffleRound`]: crate::coding::plan::ShuffleRound
    rounds: Vec<RoundLedger>,
    /// Batch epoch this ledger is accounting: bumped by every
    /// [`PhaseLedger::reset`], so a report is unambiguously tagged with
    /// the batch it measured. The pipelined executor keeps two node-state
    /// epochs in flight but meters exactly one batch at a time; the tag
    /// lets tests assert a report belongs to batch N (`epoch == N` after
    /// N resets) and that no two batches share one metering pass.
    epoch: u64,
}

impl PhaseLedger {
    pub fn new(k: usize) -> Self {
        PhaseLedger {
            bytes_by_node: vec![0; k],
            msgs_by_node: vec![0; k],
            clock_s: 0.0,
            rounds: Vec::new(),
            epoch: 0,
        }
    }

    /// Open the next round section: subsequent records account into it.
    pub fn begin_round(&mut self) {
        self.rounds.push(RoundLedger::default());
    }

    /// Append one broadcast of `nbytes` from `sender` taking `t_s`
    /// seconds on the serialized medium.
    pub fn record(&mut self, sender: usize, nbytes: usize, t_s: f64) {
        self.bytes_by_node[sender] += nbytes as u64;
        self.msgs_by_node[sender] += 1;
        self.clock_s += t_s;
        if self.rounds.is_empty() {
            self.rounds.push(RoundLedger::default());
        }
        let round = self.rounds.last_mut().unwrap();
        round.bytes += nbytes as u64;
        round.msgs += 1;
        round.elapsed_s += t_s;
    }

    /// Virtual wall-clock so far (serialized schedule).
    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    /// Per-round sections recorded so far.
    pub fn rounds(&self) -> &[RoundLedger] {
        &self.rounds
    }

    /// Batch epoch of the current accounting (number of resets so far).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn report(&self) -> NetReport {
        NetReport {
            bytes_by_node: self.bytes_by_node.clone(),
            msgs_by_node: self.msgs_by_node.clone(),
            total_bytes: self.bytes_by_node.iter().sum(),
            total_msgs: self.msgs_by_node.iter().sum(),
            elapsed_s: self.clock_s,
            rounds: self.rounds.clone(),
            epoch: self.epoch,
        }
    }

    /// Start accounting the next batch: zero the counters, drop the round
    /// sections, bump the epoch tag. O(k), keeps the round buffer's
    /// capacity.
    pub fn reset(&mut self) {
        self.bytes_by_node.iter_mut().for_each(|b| *b = 0);
        self.msgs_by_node.iter_mut().for_each(|m| *m = 0);
        self.clock_s = 0.0;
        self.rounds.clear();
        self.epoch += 1;
    }
}

/// Shared-medium broadcast network simulator: an immutable rate table
/// plus a [`PhaseLedger`] of the current phase.
#[derive(Clone, Debug)]
pub struct BroadcastNet {
    /// Per-node uplink rate, bits/second.
    pub uplink_bps: Vec<f64>,
    /// Fixed per-message latency, seconds.
    pub latency_s: f64,
    ledger: PhaseLedger,
}

/// Byte-exact accounting of one phase.
#[derive(Clone, Debug, PartialEq)]
pub struct NetReport {
    pub bytes_by_node: Vec<u64>,
    pub msgs_by_node: Vec<u64>,
    pub total_bytes: u64,
    pub total_msgs: u64,
    /// Virtual wall-clock of the serialized broadcast schedule.
    pub elapsed_s: f64,
    /// Per-round sections of the shuffle (bytes/messages/clock per
    /// [`crate::coding::plan::ShuffleRound`]) — identical across
    /// execution modes, like every other field.
    pub rounds: Vec<RoundLedger>,
    /// Batch epoch tag (ledger resets so far): after N batches through
    /// one executor this is N, in every execution mode — equality checks
    /// across modes therefore also prove both metered the same batch.
    pub epoch: u64,
}

impl BroadcastNet {
    pub fn new(uplink_bps: Vec<f64>, latency_s: f64) -> Result<Self> {
        if uplink_bps.is_empty() {
            return Err(HetcdcError::InvalidParams(
                "network needs at least one node uplink".into(),
            ));
        }
        if let Some((node, &bad)) = uplink_bps
            .iter()
            .enumerate()
            .find(|(_, &b)| !(b.is_finite() && b > 0.0))
        {
            return Err(HetcdcError::InvalidParams(format!(
                "node {node} uplink must be positive and finite, got {bad}"
            )));
        }
        if !(latency_s.is_finite() && latency_s >= 0.0) {
            return Err(HetcdcError::InvalidParams(format!(
                "latency must be non-negative and finite, got {latency_s}"
            )));
        }
        let k = uplink_bps.len();
        Ok(Self {
            uplink_bps,
            latency_s,
            ledger: PhaseLedger::new(k),
        })
    }

    /// Uniform-bandwidth convenience constructor.
    pub fn homogeneous(k: usize, uplink_bps: f64, latency_s: f64) -> Result<Self> {
        Self::new(vec![uplink_bps; k], latency_s)
    }

    /// Transmission time of one broadcast of `nbytes` from `sender` (s),
    /// without recording it.
    pub fn tx_time(&self, sender: usize, nbytes: usize) -> f64 {
        self.latency_s + (nbytes as f64 * 8.0) / self.uplink_bps[sender]
    }

    /// Record one broadcast of `nbytes` from `sender`; returns its
    /// transmission time (s).
    pub fn broadcast(&mut self, sender: usize, nbytes: usize) -> f64 {
        let t = self.tx_time(sender, nbytes);
        self.ledger.record(sender, nbytes, t);
        t
    }

    /// Open the next round section of the ledger (see
    /// [`PhaseLedger::begin_round`]).
    pub fn begin_round(&mut self) {
        self.ledger.begin_round();
    }

    /// The phase ledger accumulated so far.
    pub fn ledger(&self) -> &PhaseLedger {
        &self.ledger
    }

    pub fn report(&self) -> NetReport {
        self.ledger.report()
    }

    pub fn reset(&mut self) {
        self.ledger.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounts_bytes_and_messages() {
        let mut net = BroadcastNet::homogeneous(3, 8e6, 0.0).unwrap();
        net.broadcast(0, 1000);
        net.broadcast(0, 500);
        net.broadcast(2, 250);
        let r = net.report();
        assert_eq!(r.bytes_by_node, vec![1500, 0, 250]);
        assert_eq!(r.msgs_by_node, vec![2, 0, 1]);
        assert_eq!(r.total_bytes, 1750);
        assert_eq!(r.total_msgs, 3);
    }

    #[test]
    fn time_model_serializes_transmissions() {
        // 8 Mbit/s -> 1000 bytes = 1 ms; plus 0.1 ms latency each.
        let mut net = BroadcastNet::homogeneous(2, 8e6, 1e-4).unwrap();
        net.broadcast(0, 1000);
        net.broadcast(1, 1000);
        let r = net.report();
        assert!((r.elapsed_s - (2.0 * (1e-3 + 1e-4))).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_uplinks_differ() {
        let mut net = BroadcastNet::new(vec![8e6, 4e6], 0.0).unwrap();
        let t_fast = net.broadcast(0, 1000);
        let t_slow = net.broadcast(1, 1000);
        assert!((t_slow / t_fast - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reset_clears_state() {
        let mut net = BroadcastNet::homogeneous(2, 1e6, 0.0).unwrap();
        net.broadcast(0, 10);
        net.reset();
        let r = net.report();
        assert_eq!(r.total_bytes, 0);
        assert_eq!(r.elapsed_s, 0.0);
        assert_eq!(r.epoch, 1);
    }

    #[test]
    fn reset_tags_each_batch_epoch() {
        let mut net = BroadcastNet::homogeneous(2, 1e6, 0.0).unwrap();
        assert_eq!(net.report().epoch, 0);
        for batch in 1u64..=3 {
            net.reset();
            net.broadcast(0, 10);
            let r = net.report();
            assert_eq!(r.epoch, batch);
            assert_eq!(net.ledger().epoch(), batch);
            assert_eq!(r.total_bytes, 10, "counters restart every epoch");
        }
    }

    #[test]
    fn invalid_networks_are_typed_errors_not_panics() {
        for bad in [
            BroadcastNet::new(vec![], 0.0),
            BroadcastNet::new(vec![0.0], 0.0),
            BroadcastNet::new(vec![1e6, -5.0], 0.0),
            BroadcastNet::new(vec![1e6, f64::NAN], 0.0),
            BroadcastNet::new(vec![1e6], -1.0),
            BroadcastNet::new(vec![1e6], f64::INFINITY),
            BroadcastNet::homogeneous(0, 1e6, 0.0),
        ] {
            assert!(
                matches!(bad, Err(HetcdcError::InvalidParams(_))),
                "{bad:?}"
            );
        }
    }

    #[test]
    fn round_sections_partition_the_phase() {
        let mut net = BroadcastNet::homogeneous(2, 8e6, 1e-4).unwrap();
        net.begin_round();
        net.broadcast(0, 1000);
        net.broadcast(1, 500);
        net.begin_round();
        net.broadcast(0, 250);
        let r = net.report();
        assert_eq!(r.rounds.len(), 2);
        assert_eq!(r.rounds[0].bytes, 1500);
        assert_eq!(r.rounds[0].msgs, 2);
        assert_eq!(r.rounds[1].bytes, 250);
        assert_eq!(r.rounds[1].msgs, 1);
        assert_eq!(r.rounds.iter().map(|s| s.bytes).sum::<u64>(), r.total_bytes);
        assert_eq!(r.rounds.iter().map(|s| s.msgs).sum::<u64>(), r.total_msgs);
        // reset drops the sections with the rest of the phase state.
        net.reset();
        assert!(net.report().rounds.is_empty());
    }

    #[test]
    fn records_without_begin_round_open_an_implicit_section() {
        let mut net = BroadcastNet::homogeneous(2, 8e6, 0.0).unwrap();
        net.broadcast(0, 10);
        net.broadcast(1, 20);
        let r = net.report();
        assert_eq!(r.rounds.len(), 1);
        assert_eq!(r.rounds[0].bytes, 30);
    }

    #[test]
    fn ledger_matches_per_broadcast_times() {
        // The ledger clock is the sequential fold of tx_time in call
        // order — the exact contract the parallel executor relies on.
        let mut net = BroadcastNet::new(vec![8e6, 2e6, 4e6], 3e-4).unwrap();
        let sequence = [(0usize, 900usize), (2, 100), (1, 1200), (0, 40)];
        let mut expect = 0.0;
        for &(s, b) in &sequence {
            expect += net.tx_time(s, b);
            net.broadcast(s, b);
        }
        let r = net.ledger().report();
        assert_eq!(r.elapsed_s.to_bits(), expect.to_bits());
        assert_eq!(r.total_bytes, 900 + 100 + 1200 + 40);
        assert_eq!(r.msgs_by_node, vec![2, 1, 1]);
    }
}
