//! Simulated broadcast network with heterogeneous uplinks.
//!
//! The paper's Shuffle phase is a sequence of broadcasts on a shared
//! medium (§II): each message from node `k` reaches all other nodes. The
//! simulator byte-accounts every broadcast exactly (this is the paper's
//! communication-load metric, measured rather than predicted) and advances
//! a virtual clock: node `k` transmits at `uplink_bps[k]`, transmissions
//! on the shared medium serialize, and each message pays a fixed `latency`
//! (the EC2-style per-message overhead that makes many small messages
//! slower than few large ones — why coded shuffle also wins wall-clock).
//!
//! Under a switched [`Topology`] the one medium becomes a table of links
//! (node access links, rack trunks — see [`crate::net::topology`]) and
//! the clock becomes a **schedule**: multicast groups of the same
//! [`ShuffleRound`] run concurrently when their links are disjoint, a
//! round's `makespan_s` is the max over its groups' finish times rather
//! than the sum, and rounds are barriers (round `i+1` starts when round
//! `i`'s slowest group finishes). `Topology::Shared` keeps the original
//! serialized fold bit-for-bit.
//!
//! Accounting lives in [`PhaseLedger`], a plain-data (`Send + Sync`)
//! record separate from the rate table, so the parallel executor can keep
//! the metering pass on one thread — in exact plan order, preserving the
//! bit-exact clock — while decode workers run concurrently. The clock
//! (and, under a switched topology, the per-link `free_at` schedule) is a
//! float fold over per-broadcast times; float arithmetic is not
//! associative, so the ledger is never merged from per-worker partials:
//! every broadcast is recorded through the same sequential
//! [`BroadcastNet::broadcast`] path in every execution mode.
//!
//! This substitutes for the paper's EC2 testbed (DESIGN.md §4): the
//! load metric is exact; the time model preserves the who-wins ordering.
//!
//! [`ShuffleRound`]: crate::coding::plan::ShuffleRound

use crate::error::{HetcdcError, Result};
use crate::net::topology::{LinkTable, Topology};

/// Byte/message/clock accounting of one shuffle *round* — one section of
/// a [`PhaseLedger`]. `elapsed_s` is the round's own sequential float
/// fold (the serialized schedule); the phase total is folded separately
/// (float addition is not associative, so the per-round sums are not
/// re-added into the total). `makespan_s` is the concurrent schedule
/// length of the round under the network's [`Topology`]; on the shared
/// medium nothing is concurrent, so it is the identical fold as
/// `elapsed_s`, bit for bit.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundLedger {
    pub bytes: u64,
    pub msgs: u64,
    pub elapsed_s: f64,
    /// Concurrent schedule length of the round (== `elapsed_s` on the
    /// shared medium, <= it on switched topologies). Straggler waits
    /// (below) are part of the schedule, so they extend this field but
    /// never `elapsed_s`, which stays the pure transmission fold.
    pub makespan_s: f64,
    /// Index within the round of the multicast group whose finish time
    /// set the makespan — the round's critical path. `None` on the
    /// shared medium, where no group is distinguished.
    pub critical_group: Option<usize>,
    /// Total time this round's schedule sat waiting for straggling
    /// senders (nodes whose Map overran the nominal barrier — see
    /// [`crate::net::FaultSpec`]). 0 when no straggle is configured, so
    /// fault-free ledgers are unchanged.
    pub straggler_delay_s: f64,
    /// The sender whose readiness wait in this round was largest — the
    /// slowest transversal of the straggler critical path. `None` when
    /// no send waited.
    pub critical_node: Option<usize>,
}

/// Byte/occupancy accounting of one link of a switched topology. Empty
/// on `Topology::Shared`, which has no links.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinkLedger {
    /// Stable link name (`node{i}` access links, `rack{r}` trunks).
    pub id: String,
    pub bytes: u64,
    pub msgs: u64,
    /// Total time the link was occupied by transmissions.
    pub busy_s: f64,
    /// `busy_s / elapsed_s` of the phase (0 when the clock never moved).
    pub utilization: f64,
}

/// Byte/message/clock accounting of one phase, separated from the rate
/// table so it can travel across threads (plain data, `Send + Sync`).
///
/// Records must be appended in broadcast order via [`PhaseLedger::record`]
/// (or the scheduled path driven by [`BroadcastNet::broadcast`]) — the
/// clock is an order-sensitive float fold (see module docs). Round
/// boundaries ([`PhaseLedger::begin_round`]) segment the same sequential
/// pass into per-round sections; they never change the totals.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseLedger {
    bytes_by_node: Vec<u64>,
    msgs_by_node: Vec<u64>,
    clock_s: f64,
    /// Per-round sections of the current phase (the multi-round shuffle
    /// IR: the executor opens one section per [`ShuffleRound`]). Records
    /// arriving before any `begin_round` fall into an implicit first
    /// section, so round-less callers (ad-hoc benches, prediction of
    /// legacy plans) still account correctly.
    ///
    /// [`ShuffleRound`]: crate::coding::plan::ShuffleRound
    rounds: Vec<RoundLedger>,
    /// Per-link occupancy of the current phase; empty on the shared
    /// medium. Link identity/rates live in the net's immutable
    /// [`LinkTable`]; only the mutable counters live here.
    links: Vec<LinkLedger>,
    /// Per-link absolute virtual time at which the link next frees up —
    /// the scheduler state of the switched-topology path. Same length as
    /// `links`.
    free_at: Vec<f64>,
    /// Absolute clock at which the current round began (= previous
    /// round's end; rounds are barriers).
    round_base: f64,
    /// Absolute clock of the slowest finish seen in the current round.
    round_end: f64,
    /// Index the next `begin_group` in this round will take.
    next_group: usize,
    /// Currently open multicast group, if any.
    cur_group: Option<usize>,
    /// Node bitmask of the open group's members (senders + decoding
    /// destinations) — decides whether a broadcast leaves its rack.
    group_members: u32,
    /// Finish time of the open group's previous broadcast: broadcasts
    /// within one group chain sequentially (destinations decode them in
    /// order), concurrency exists only *across* groups.
    group_prev_finish: f64,
    /// Per-node readiness times of the straggler model: node `i` may not
    /// transmit before `ready[i]` (its Map overran the nominal barrier
    /// by that much). Empty when no straggle is configured — every wait
    /// computation is skipped and the fault-free fold is bit-identical
    /// to the pre-fault code path. Survives [`PhaseLedger::reset`]: the
    /// jitter is a property of the cluster, not of one batch.
    ready: Vec<f64>,
    /// Largest single readiness wait seen in the current round (drives
    /// [`RoundLedger::critical_node`]).
    round_max_wait: f64,
    /// Broadcasts transmitted but received by nobody this batch (the
    /// runtime erasure model, [`crate::net::Erase`]). 0 when fault-free.
    erased_broadcasts: u64,
    /// Retransmission recovery sweeps this batch (see
    /// [`PhaseLedger::begin_retransmit_round`]).
    retransmit_rounds: u64,
    /// Wire bytes moved by recovery unicasts this batch.
    recovery_bytes: u64,
    /// NACK round trips paid by recovery unicasts this batch.
    nack_rtts: u64,
    /// Batch epoch this ledger is accounting: bumped by every
    /// [`PhaseLedger::reset`], so a report is unambiguously tagged with
    /// the batch it measured. The pipelined executor keeps two node-state
    /// epochs in flight but meters exactly one batch at a time; the tag
    /// lets tests assert a report belongs to batch N (`epoch == N` after
    /// N resets) and that no two batches share one metering pass.
    epoch: u64,
}

impl PhaseLedger {
    pub fn new(k: usize) -> Self {
        Self::with_links(k, Vec::new())
    }

    /// Ledger over `k` nodes and the named links of a switched topology
    /// (empty for the shared medium).
    pub fn with_links(k: usize, link_ids: Vec<String>) -> Self {
        let links: Vec<LinkLedger> = link_ids
            .into_iter()
            .map(|id| LinkLedger {
                id,
                ..LinkLedger::default()
            })
            .collect();
        let n_links = links.len();
        PhaseLedger {
            bytes_by_node: vec![0; k],
            msgs_by_node: vec![0; k],
            clock_s: 0.0,
            rounds: Vec::new(),
            links,
            free_at: vec![0.0; n_links],
            round_base: 0.0,
            round_end: 0.0,
            next_group: 0,
            cur_group: None,
            group_members: 0,
            group_prev_finish: 0.0,
            ready: Vec::new(),
            round_max_wait: 0.0,
            erased_broadcasts: 0,
            retransmit_rounds: 0,
            recovery_bytes: 0,
            nack_rtts: 0,
            epoch: 0,
        }
    }

    /// Note one erased broadcast: it was transmitted (and recorded via
    /// the usual path — the sender's bytes and clock are unchanged by
    /// the loss) but reached no receiver.
    pub fn note_erased(&mut self) {
        self.erased_broadcasts += 1;
    }

    /// Open one retransmission recovery sweep: the stranded receivers'
    /// NACK/backoff window of `backoff_s` elapses before any resend. The
    /// wait extends the schedule (clock and the current round's
    /// makespan) but never `elapsed_s`, which stays the pure
    /// transmission fold — the same split the straggler model uses.
    pub fn begin_retransmit_round(&mut self, backoff_s: f64) {
        self.retransmit_rounds += 1;
        if self.rounds.is_empty() {
            self.rounds.push(RoundLedger::default());
        }
        if backoff_s > 0.0 {
            self.clock_s += backoff_s;
            if let Some(round) = self.rounds.last_mut() {
                round.makespan_s += backoff_s;
            }
            self.round_end = self.clock_s;
        }
    }

    /// Append one recovery unicast of `nbytes` from `sender`, preceded
    /// by its NACK travel time `nack_wait_s` and taking `t_s` on the
    /// sender's uplink. Recovery traffic accounts into the current
    /// (last) round section, so per-round byte sums still re-add to the
    /// phase total; `recovery_bytes`/`nack_rtts` break it out.
    pub fn record_retransmit(&mut self, sender: usize, nbytes: usize, nack_wait_s: f64, t_s: f64) {
        self.bytes_by_node[sender] += nbytes as u64;
        self.msgs_by_node[sender] += 1;
        self.recovery_bytes += nbytes as u64;
        self.nack_rtts += 1;
        if self.rounds.is_empty() {
            self.rounds.push(RoundLedger::default());
        }
        self.clock_s += nack_wait_s + t_s;
        if let Some(round) = self.rounds.last_mut() {
            round.bytes += nbytes as u64;
            round.msgs += 1;
            round.elapsed_s += t_s;
            round.makespan_s += nack_wait_s + t_s;
        }
        self.round_end = self.clock_s;
    }

    /// Install per-node readiness times (seconds past the nominal Map
    /// barrier at which each node may start sending). Clears the
    /// straggler path when every entry is zero, keeping the fault-free
    /// fold on the exact pre-fault code path.
    pub fn set_straggle(&mut self, ready: &[f64]) {
        assert_eq!(ready.len(), self.bytes_by_node.len(), "ready times per node");
        if ready.iter().all(|&t| t == 0.0) {
            self.ready.clear();
        } else {
            self.ready = ready.to_vec();
        }
    }

    /// Open the next round section: subsequent records account into it.
    /// Under a switched topology this is also the round barrier: the new
    /// round's schedule starts where the previous round's slowest group
    /// finished.
    pub fn begin_round(&mut self) {
        self.rounds.push(RoundLedger::default());
        self.round_base = self.round_end;
        self.next_group = 0;
        self.cur_group = None;
        self.group_members = 0;
        self.group_prev_finish = self.round_base;
        self.round_max_wait = 0.0;
    }

    /// Open the next multicast group of the current round. Scheduled
    /// (switched-topology) accounting only — on the shared medium groups
    /// carry no timing meaning and this is a no-op, keeping the original
    /// serialized fold untouched.
    pub fn begin_group(&mut self, members: u32) {
        if self.links.is_empty() {
            return;
        }
        self.cur_group = Some(self.next_group);
        self.next_group += 1;
        self.group_members = members;
        self.group_prev_finish = self.round_base;
    }

    /// Whether a multicast group is currently open (switched path).
    pub fn group_open(&self) -> bool {
        self.cur_group.is_some()
    }

    pub(crate) fn group_members(&self) -> u32 {
        self.group_members
    }

    /// Append one broadcast of `nbytes` from `sender` taking `t_s`
    /// seconds on the serialized shared medium. A straggling sender
    /// whose readiness time lies past the current clock first stalls the
    /// medium until it is ready; the stall is accounted as
    /// [`RoundLedger::straggler_delay_s`], never as `elapsed_s`.
    pub fn record(&mut self, sender: usize, nbytes: usize, t_s: f64) {
        self.bytes_by_node[sender] += nbytes as u64;
        self.msgs_by_node[sender] += 1;
        if self.rounds.is_empty() {
            self.rounds.push(RoundLedger::default());
            self.round_max_wait = 0.0;
        }
        if !self.ready.is_empty() {
            let wait = self.ready[sender] - self.clock_s;
            if wait > 0.0 {
                self.clock_s += wait;
                let round = self.rounds.last_mut().unwrap();
                round.straggler_delay_s += wait;
                round.makespan_s += wait;
                if wait > self.round_max_wait {
                    self.round_max_wait = wait;
                    round.critical_node = Some(sender);
                }
            }
        }
        self.clock_s += t_s;
        let round = self.rounds.last_mut().unwrap();
        round.bytes += nbytes as u64;
        round.msgs += 1;
        round.elapsed_s += t_s;
        // Identical fold as elapsed_s — bitwise equal on the shared
        // medium (without stragglers), by construction.
        round.makespan_s += t_s;
    }

    /// Append one broadcast of `nbytes` from `sender` onto the
    /// switched-link schedule. `used` lists the `(link, rate_bps)` pairs
    /// the transmission occupies (access link, plus the rack trunk when
    /// it leaves the rack); the transfer rate is the min over used links.
    /// Returns the broadcast's transmission time.
    pub(crate) fn record_scheduled(
        &mut self,
        sender: usize,
        nbytes: usize,
        latency_s: f64,
        used: &[(usize, f64)],
    ) -> f64 {
        self.bytes_by_node[sender] += nbytes as u64;
        self.msgs_by_node[sender] += 1;
        if self.rounds.is_empty() {
            self.rounds.push(RoundLedger::default());
            self.round_base = self.round_end;
            self.next_group = 0;
            self.group_prev_finish = self.round_base;
            self.round_max_wait = 0.0;
        }
        if self.cur_group.is_none() {
            // Round-less / group-less caller: open an implicit group so
            // the schedule still chains deterministically.
            self.cur_group = Some(self.next_group);
            self.next_group += 1;
            self.group_prev_finish = self.round_base;
        }
        let bits = nbytes as f64 * 8.0;
        let mut min_rate = f64::INFINITY;
        let mut start = self.group_prev_finish;
        for &(l, rate) in used {
            if rate < min_rate {
                min_rate = rate;
            }
            if self.free_at[l] > start {
                start = self.free_at[l];
            }
        }
        // A straggling sender holds its whole transmission (and the
        // links it occupies) until it is ready.
        let mut wait = 0.0;
        if !self.ready.is_empty() && self.ready[sender] > start {
            wait = self.ready[sender] - start;
            start = self.ready[sender];
        }
        let t_total = latency_s + bits / min_rate;
        let finish = start + t_total;
        for &(l, rate) in used {
            let occupancy = latency_s + bits / rate;
            self.free_at[l] = start + occupancy;
            let link = &mut self.links[l];
            link.bytes += nbytes as u64;
            link.msgs += 1;
            link.busy_s += occupancy;
        }
        self.group_prev_finish = finish;
        let round = self.rounds.last_mut().unwrap();
        round.bytes += nbytes as u64;
        round.msgs += 1;
        round.elapsed_s += t_total;
        if wait > 0.0 {
            round.straggler_delay_s += wait;
            if wait > self.round_max_wait {
                self.round_max_wait = wait;
                round.critical_node = Some(sender);
            }
        }
        if finish > self.round_end {
            self.round_end = finish;
            round.critical_group = self.cur_group;
        }
        round.makespan_s = self.round_end - self.round_base;
        self.clock_s = self.round_end;
        t_total
    }

    /// Virtual wall-clock so far: the serialized schedule on the shared
    /// medium, the concurrent schedule's end under a switched topology.
    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    /// Per-round sections recorded so far.
    pub fn rounds(&self) -> &[RoundLedger] {
        &self.rounds
    }

    /// Per-link occupancy recorded so far (empty on the shared medium).
    pub fn links(&self) -> &[LinkLedger] {
        &self.links
    }

    /// Batch epoch of the current accounting (number of resets so far).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn report(&self) -> NetReport {
        let links = self
            .links
            .iter()
            .map(|l| LinkLedger {
                utilization: if self.clock_s > 0.0 {
                    l.busy_s / self.clock_s
                } else {
                    0.0
                },
                ..l.clone()
            })
            .collect();
        NetReport {
            bytes_by_node: self.bytes_by_node.clone(),
            msgs_by_node: self.msgs_by_node.clone(),
            total_bytes: self.bytes_by_node.iter().sum(),
            total_msgs: self.msgs_by_node.iter().sum(),
            elapsed_s: self.clock_s,
            straggler_delay_s: self.rounds.iter().map(|r| r.straggler_delay_s).sum(),
            rounds: self.rounds.clone(),
            links,
            erased_broadcasts: self.erased_broadcasts,
            retransmit_rounds: self.retransmit_rounds,
            recovery_bytes: self.recovery_bytes,
            nack_rtts: self.nack_rtts,
            epoch: self.epoch,
        }
    }

    /// Start accounting the next batch: zero the counters, drop the round
    /// sections, rewind the link schedule, bump the epoch tag. O(k + L),
    /// keeps the round buffer's capacity and the link names.
    pub fn reset(&mut self) {
        self.bytes_by_node.iter_mut().for_each(|b| *b = 0);
        self.msgs_by_node.iter_mut().for_each(|m| *m = 0);
        self.clock_s = 0.0;
        self.rounds.clear();
        for link in &mut self.links {
            link.bytes = 0;
            link.msgs = 0;
            link.busy_s = 0.0;
            link.utilization = 0.0;
        }
        self.free_at.iter_mut().for_each(|t| *t = 0.0);
        self.round_base = 0.0;
        self.round_end = 0.0;
        self.next_group = 0;
        self.cur_group = None;
        self.group_members = 0;
        self.group_prev_finish = 0.0;
        // `ready` is deliberately kept: the straggler jitter belongs to
        // the cluster, and every batch replays the same schedule.
        self.round_max_wait = 0.0;
        self.erased_broadcasts = 0;
        self.retransmit_rounds = 0;
        self.recovery_bytes = 0;
        self.nack_rtts = 0;
        self.epoch += 1;
    }
}

/// Broadcast network simulator: an immutable rate table (per-node
/// uplinks plus, for switched topologies, a [`LinkTable`]) and a
/// [`PhaseLedger`] of the current phase.
#[derive(Clone, Debug)]
pub struct BroadcastNet {
    /// Per-node uplink rate, bits/second.
    pub uplink_bps: Vec<f64>,
    /// Fixed per-message latency, seconds.
    pub latency_s: f64,
    topology: Topology,
    /// Switched-link rate table; `None` on the shared medium.
    links: Option<LinkTable>,
    ledger: PhaseLedger,
}

/// Byte-exact accounting of one phase.
#[derive(Clone, Debug, PartialEq)]
pub struct NetReport {
    pub bytes_by_node: Vec<u64>,
    pub msgs_by_node: Vec<u64>,
    pub total_bytes: u64,
    pub total_msgs: u64,
    /// Virtual wall-clock of the broadcast schedule: serialized on the
    /// shared medium, concurrent-group makespan under a switched
    /// topology. The topology changes this field only — never the byte
    /// or message counts. Straggler waits are part of the schedule and
    /// are included here (and broken out in `straggler_delay_s`).
    pub elapsed_s: f64,
    /// Total time the schedule sat waiting for straggling senders,
    /// summed over rounds. 0 when no straggle is configured — like the
    /// topology, a fault spec changes schedule fields only, never a
    /// byte, message, or round count.
    pub straggler_delay_s: f64,
    /// Per-round sections of the shuffle (bytes/messages/clock per
    /// [`crate::coding::plan::ShuffleRound`]) — identical across
    /// execution modes, like every other field.
    pub rounds: Vec<RoundLedger>,
    /// Per-link occupancy/utilization under a switched topology; empty
    /// on the shared medium.
    pub links: Vec<LinkLedger>,
    /// Broadcasts transmitted but received by nobody (the runtime
    /// erasure model). All four recovery counters are 0 on fault-free
    /// runs and omitted from serialized reports when 0, keeping
    /// fault-free artifacts byte-identical to the pre-erasure era.
    pub erased_broadcasts: u64,
    /// Retransmission recovery sweeps run after the planned rounds.
    pub retransmit_rounds: u64,
    /// Wire bytes moved by recovery unicasts (included in the totals,
    /// broken out here).
    pub recovery_bytes: u64,
    /// NACK round trips paid by recovery unicasts.
    pub nack_rtts: u64,
    /// Batch epoch tag (ledger resets so far): after N batches through
    /// one executor this is N, in every execution mode — equality checks
    /// across modes therefore also prove both metered the same batch.
    pub epoch: u64,
}

impl BroadcastNet {
    /// Shared-medium network (the §II model; default everywhere).
    pub fn new(uplink_bps: Vec<f64>, latency_s: f64) -> Result<Self> {
        Self::with_topology(uplink_bps, latency_s, Topology::Shared)
    }

    /// Network with an explicit [`Topology`]. Rejects empty or
    /// non-positive/non-finite node and link rates and bad latency with
    /// typed [`HetcdcError::InvalidParams`] — a zero rate would
    /// otherwise poison the virtual clock with inf/NaN.
    pub fn with_topology(
        uplink_bps: Vec<f64>,
        latency_s: f64,
        topology: Topology,
    ) -> Result<Self> {
        if uplink_bps.is_empty() {
            return Err(HetcdcError::InvalidParams(
                "network needs at least one node uplink".into(),
            ));
        }
        if let Some((node, &bad)) = uplink_bps
            .iter()
            .enumerate()
            .find(|(_, &b)| !(b.is_finite() && b > 0.0))
        {
            return Err(HetcdcError::InvalidParams(format!(
                "node {node} uplink must be positive and finite, got {bad}"
            )));
        }
        if !(latency_s.is_finite() && latency_s >= 0.0) {
            return Err(HetcdcError::InvalidParams(format!(
                "latency must be non-negative and finite, got {latency_s}"
            )));
        }
        let links = topology.link_table(&uplink_bps)?;
        let k = uplink_bps.len();
        let ledger = match &links {
            Some(table) => PhaseLedger::with_links(k, table.ids.clone()),
            None => PhaseLedger::new(k),
        };
        Ok(Self {
            uplink_bps,
            latency_s,
            topology,
            links,
            ledger,
        })
    }

    /// Uniform-bandwidth convenience constructor (shared medium).
    pub fn homogeneous(k: usize, uplink_bps: f64, latency_s: f64) -> Result<Self> {
        Self::new(vec![uplink_bps; k], latency_s)
    }

    /// The network's topology.
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Transmission time of one broadcast of `nbytes` from `sender` (s)
    /// on the shared medium / the sender's access link, without
    /// recording it. Under a switched topology the scheduled time can
    /// exceed this when a slower rack trunk bottlenecks the transfer.
    pub fn tx_time(&self, sender: usize, nbytes: usize) -> f64 {
        self.latency_s + (nbytes as f64 * 8.0) / self.uplink_bps[sender]
    }

    /// Record one broadcast of `nbytes` from `sender`; returns its
    /// transmission time (s). On the shared medium this serializes after
    /// everything already recorded; under a switched topology it is
    /// placed on the link schedule (see [`PhaseLedger::record_scheduled`]).
    pub fn broadcast(&mut self, sender: usize, nbytes: usize) -> f64 {
        match &self.links {
            None => {
                let t = self.tx_time(sender, nbytes);
                self.ledger.record(sender, nbytes, t);
                t
            }
            Some(table) => {
                if !self.ledger.group_open() {
                    // Group-less caller: everything is one implicit
                    // broadcast-domain group (conservative — trunk
                    // traffic assumed).
                    let k = self.uplink_bps.len();
                    let full = if k >= 32 { u32::MAX } else { (1u32 << k) - 1 };
                    self.ledger.begin_group(full);
                }
                let members = self.ledger.group_members();
                let mut used = [(0usize, 0.0f64); 2];
                used[0] = (sender, table.rates_bps[sender]);
                let mut n_used = 1;
                if let Some(agg) = table.agg[sender] {
                    if members & !table.rack_mask[sender] != 0 {
                        used[n_used] = (agg, table.rates_bps[agg]);
                        n_used += 1;
                    }
                }
                self.ledger
                    .record_scheduled(sender, nbytes, self.latency_s, &used[..n_used])
            }
        }
    }

    /// Note one erased broadcast (already transmitted and recorded via
    /// [`BroadcastNet::broadcast`] — the loss is at the receivers, so
    /// bytes and clock are unchanged; only the counter moves).
    pub fn note_erased(&mut self) {
        self.ledger.note_erased();
    }

    /// Open retransmission recovery sweep `round` (1-based): the NACK
    /// backoff window `latency * 2^(round-1)` elapses before resends.
    pub fn begin_retransmit_round(&mut self, round: usize) {
        let backoff_s = self.latency_s * f64::powi(2.0, round.saturating_sub(1) as i32);
        self.ledger.begin_retransmit_round(backoff_s);
    }

    /// Record one reliable recovery unicast of `nbytes` from `sender`:
    /// one NACK travel (`latency`) plus the resend on the sender's
    /// uplink ([`BroadcastNet::tx_time`], which pays the per-message
    /// latency again — together the NACK round trip). Returns the total
    /// time charged. Recovery unicasts bypass the erasure model: they
    /// are acknowledged point-to-point resends, so recovery always
    /// terminates, even at `p=1`.
    pub fn retransmit_unicast(&mut self, sender: usize, nbytes: usize) -> f64 {
        let t = self.tx_time(sender, nbytes);
        self.ledger.record_retransmit(sender, nbytes, self.latency_s, t);
        self.latency_s + t
    }

    /// Install the straggler readiness times (seconds past the nominal
    /// Map barrier before each node may send — see
    /// [`PhaseLedger::set_straggle`]). Rejects negative or non-finite
    /// times. The times persist across [`BroadcastNet::reset`]: every
    /// batch replays the same jitter.
    pub fn set_straggle(&mut self, ready: &[f64]) -> Result<()> {
        if ready.len() != self.uplink_bps.len() {
            return Err(HetcdcError::InvalidParams(format!(
                "straggler readiness needs one time per node: got {} for {} nodes",
                ready.len(),
                self.uplink_bps.len()
            )));
        }
        if let Some(&bad) = ready.iter().find(|t| !(t.is_finite() && **t >= 0.0)) {
            return Err(HetcdcError::InvalidParams(format!(
                "straggler readiness times must be non-negative and finite, got {bad}"
            )));
        }
        self.ledger.set_straggle(ready);
        Ok(())
    }

    /// Open the next round section of the ledger (see
    /// [`PhaseLedger::begin_round`]).
    pub fn begin_round(&mut self) {
        self.ledger.begin_round();
    }

    /// Open the next multicast group of the current round, naming its
    /// member set (see [`PhaseLedger::begin_group`]). No-op on the
    /// shared medium.
    pub fn begin_group(&mut self, members: u32) {
        self.ledger.begin_group(members);
    }

    /// The phase ledger accumulated so far.
    pub fn ledger(&self) -> &PhaseLedger {
        &self.ledger
    }

    pub fn report(&self) -> NetReport {
        self.ledger.report()
    }

    pub fn reset(&mut self) {
        self.ledger.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounts_bytes_and_messages() {
        let mut net = BroadcastNet::homogeneous(3, 8e6, 0.0).unwrap();
        net.broadcast(0, 1000);
        net.broadcast(0, 500);
        net.broadcast(2, 250);
        let r = net.report();
        assert_eq!(r.bytes_by_node, vec![1500, 0, 250]);
        assert_eq!(r.msgs_by_node, vec![2, 0, 1]);
        assert_eq!(r.total_bytes, 1750);
        assert_eq!(r.total_msgs, 3);
    }

    #[test]
    fn time_model_serializes_transmissions() {
        // 8 Mbit/s -> 1000 bytes = 1 ms; plus 0.1 ms latency each.
        let mut net = BroadcastNet::homogeneous(2, 8e6, 1e-4).unwrap();
        net.broadcast(0, 1000);
        net.broadcast(1, 1000);
        let r = net.report();
        assert!((r.elapsed_s - (2.0 * (1e-3 + 1e-4))).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_uplinks_differ() {
        let mut net = BroadcastNet::new(vec![8e6, 4e6], 0.0).unwrap();
        let t_fast = net.broadcast(0, 1000);
        let t_slow = net.broadcast(1, 1000);
        assert!((t_slow / t_fast - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reset_clears_state() {
        let mut net = BroadcastNet::homogeneous(2, 1e6, 0.0).unwrap();
        net.broadcast(0, 10);
        net.reset();
        let r = net.report();
        assert_eq!(r.total_bytes, 0);
        assert_eq!(r.elapsed_s, 0.0);
        assert_eq!(r.epoch, 1);
    }

    #[test]
    fn reset_tags_each_batch_epoch() {
        let mut net = BroadcastNet::homogeneous(2, 1e6, 0.0).unwrap();
        assert_eq!(net.report().epoch, 0);
        for batch in 1u64..=3 {
            net.reset();
            net.broadcast(0, 10);
            let r = net.report();
            assert_eq!(r.epoch, batch);
            assert_eq!(net.ledger().epoch(), batch);
            assert_eq!(r.total_bytes, 10, "counters restart every epoch");
        }
    }

    #[test]
    fn invalid_networks_are_typed_errors_not_panics() {
        for bad in [
            BroadcastNet::new(vec![], 0.0),
            BroadcastNet::new(vec![0.0], 0.0),
            BroadcastNet::new(vec![1e6, -5.0], 0.0),
            BroadcastNet::new(vec![1e6, f64::NAN], 0.0),
            BroadcastNet::new(vec![1e6], -1.0),
            BroadcastNet::new(vec![1e6], f64::INFINITY),
            BroadcastNet::homogeneous(0, 1e6, 0.0),
            BroadcastNet::with_topology(
                vec![1e6, 1e6],
                0.0,
                Topology::Rack { racks: 0, oversub: 2.0 },
            ),
            BroadcastNet::with_topology(
                vec![1e6, 1e6],
                0.0,
                Topology::Rack { racks: 2, oversub: 0.0 },
            ),
            BroadcastNet::with_topology(
                vec![1e6, 1e6],
                0.0,
                Topology::Rack { racks: 2, oversub: f64::NAN },
            ),
        ] {
            assert!(
                matches!(bad, Err(HetcdcError::InvalidParams(_))),
                "{bad:?}"
            );
        }
    }

    #[test]
    fn round_sections_partition_the_phase() {
        let mut net = BroadcastNet::homogeneous(2, 8e6, 1e-4).unwrap();
        net.begin_round();
        net.broadcast(0, 1000);
        net.broadcast(1, 500);
        net.begin_round();
        net.broadcast(0, 250);
        let r = net.report();
        assert_eq!(r.rounds.len(), 2);
        assert_eq!(r.rounds[0].bytes, 1500);
        assert_eq!(r.rounds[0].msgs, 2);
        assert_eq!(r.rounds[1].bytes, 250);
        assert_eq!(r.rounds[1].msgs, 1);
        assert_eq!(r.rounds.iter().map(|s| s.bytes).sum::<u64>(), r.total_bytes);
        assert_eq!(r.rounds.iter().map(|s| s.msgs).sum::<u64>(), r.total_msgs);
        // reset drops the sections with the rest of the phase state.
        net.reset();
        assert!(net.report().rounds.is_empty());
    }

    #[test]
    fn records_without_begin_round_open_an_implicit_section() {
        let mut net = BroadcastNet::homogeneous(2, 8e6, 0.0).unwrap();
        net.broadcast(0, 10);
        net.broadcast(1, 20);
        let r = net.report();
        assert_eq!(r.rounds.len(), 1);
        assert_eq!(r.rounds[0].bytes, 30);
    }

    #[test]
    fn ledger_matches_per_broadcast_times() {
        // The ledger clock is the sequential fold of tx_time in call
        // order — the exact contract the parallel executor relies on.
        let mut net = BroadcastNet::new(vec![8e6, 2e6, 4e6], 3e-4).unwrap();
        let sequence = [(0usize, 900usize), (2, 100), (1, 1200), (0, 40)];
        let mut expect = 0.0;
        for &(s, b) in &sequence {
            expect += net.tx_time(s, b);
            net.broadcast(s, b);
        }
        let r = net.ledger().report();
        assert_eq!(r.elapsed_s.to_bits(), expect.to_bits());
        assert_eq!(r.total_bytes, 900 + 100 + 1200 + 40);
        assert_eq!(r.msgs_by_node, vec![2, 1, 1]);
    }

    #[test]
    fn shared_medium_folds_makespan_identically_to_elapsed() {
        let mut net = BroadcastNet::new(vec![8e6, 2e6], 3e-4).unwrap();
        net.begin_round();
        net.begin_group(0b11);
        net.broadcast(0, 900);
        net.broadcast(1, 100);
        net.begin_round();
        net.broadcast(0, 40);
        for round in net.report().rounds {
            assert_eq!(round.makespan_s.to_bits(), round.elapsed_s.to_bits());
            assert_eq!(round.critical_group, None);
        }
        assert!(net.report().links.is_empty());
    }

    #[test]
    fn disjoint_groups_run_concurrently_on_flat_topology() {
        // Two single-broadcast groups from different senders in one
        // round: flat topology runs them concurrently, so the round's
        // makespan is the max, not the sum.
        let mk = |topo| {
            let mut net =
                BroadcastNet::with_topology(vec![8e6, 4e6], 0.0, topo).unwrap();
            net.begin_round();
            net.begin_group(0b01);
            net.broadcast(0, 1000); // 1 ms on node0's link
            net.begin_group(0b10);
            net.broadcast(1, 1000); // 2 ms on node1's link
            net.report()
        };
        let flat = mk(Topology::Flat);
        let shared = mk(Topology::Shared);
        assert_eq!(flat.total_bytes, shared.total_bytes);
        assert_eq!(flat.rounds.len(), shared.rounds.len());
        assert!((flat.elapsed_s - 2e-3).abs() < 1e-12);
        assert!((shared.elapsed_s - 3e-3).abs() < 1e-12);
        assert_eq!(flat.rounds[0].critical_group, Some(1));
        assert_eq!(flat.links.len(), 2);
        assert_eq!(flat.links[0].bytes, 1000);
        assert_eq!(flat.links[1].bytes, 1000);
        assert!((flat.links[1].utilization - 1.0).abs() < 1e-12);
    }

    #[test]
    fn broadcasts_within_a_group_chain_sequentially() {
        let mut net = BroadcastNet::with_topology(vec![8e6, 8e6], 0.0, Topology::Flat).unwrap();
        net.begin_round();
        net.begin_group(0b11);
        net.broadcast(0, 1000);
        net.broadcast(1, 1000); // different link, same group: chained
        let r = net.report();
        assert!((r.elapsed_s - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn rounds_are_barriers_on_switched_topologies() {
        let mut net = BroadcastNet::with_topology(vec![8e6, 4e6], 0.0, Topology::Flat).unwrap();
        net.begin_round();
        net.begin_group(0b10);
        net.broadcast(1, 1000); // 2 ms: round 1 ends at 2 ms
        net.begin_round();
        net.begin_group(0b01);
        net.broadcast(0, 1000); // starts at the barrier, +1 ms
        let r = net.report();
        assert!((r.rounds[0].makespan_s - 2e-3).abs() < 1e-12);
        assert!((r.rounds[1].makespan_s - 1e-3).abs() < 1e-12);
        assert!((r.elapsed_s - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn rack_trunk_carries_only_cross_rack_traffic() {
        // 2 racks of 2; trunk rate = (8+8)/4 = 4 Mbit/s.
        let topo = Topology::Rack { racks: 2, oversub: 4.0 };
        let mut net =
            BroadcastNet::with_topology(vec![8e6; 4], 0.0, topo).unwrap();
        net.begin_round();
        net.begin_group(0b0011); // stays inside rack 0
        net.broadcast(0, 1000);
        net.begin_round();
        net.begin_group(0b0101); // node0 -> node2 crosses racks
        net.broadcast(0, 1000);
        let r = net.report();
        let trunk0 = &r.links[4];
        assert_eq!(trunk0.id, "rack0");
        assert_eq!(trunk0.bytes, 1000, "only the cross-rack broadcast");
        // In-rack broadcast runs at the access rate (1 ms); cross-rack
        // is bottlenecked by the 4 Mbit/s trunk (2 ms).
        assert!((r.rounds[0].makespan_s - 1e-3).abs() < 1e-12);
        assert!((r.rounds[1].makespan_s - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn groups_sharing_a_trunk_serialize_on_it() {
        // Both senders sit in rack 0 and cross racks: their group
        // schedules collide on the rack0 trunk.
        let topo = Topology::Rack { racks: 2, oversub: 2.0 };
        let mut net =
            BroadcastNet::with_topology(vec![8e6; 4], 0.0, topo).unwrap();
        net.begin_round();
        net.begin_group(0b0101);
        net.broadcast(0, 1000); // trunk busy 0..1ms (trunk rate 8e6)
        net.begin_group(0b1010);
        net.broadcast(1, 1000); // waits for the trunk: 1..2ms
        let r = net.report();
        assert!((r.rounds[0].makespan_s - 2e-3).abs() < 1e-12);
        assert_eq!(r.rounds[0].critical_group, Some(1));
    }

    #[test]
    fn straggler_wait_stalls_shared_medium_and_is_accounted() {
        // 8 Mbit/s -> 1000 bytes = 1 ms. Node 1 is ready only at 5 ms.
        let mut net = BroadcastNet::homogeneous(2, 8e6, 0.0).unwrap();
        net.set_straggle(&[0.0, 5e-3]).unwrap();
        net.begin_round();
        net.broadcast(0, 1000); // 0..1 ms
        net.broadcast(1, 1000); // waits 4 ms, 5..6 ms
        let r = net.report();
        assert!((r.elapsed_s - 6e-3).abs() < 1e-12);
        assert!((r.straggler_delay_s - 4e-3).abs() < 1e-12);
        let round = &r.rounds[0];
        assert!((round.straggler_delay_s - 4e-3).abs() < 1e-12);
        assert!((round.makespan_s - 6e-3).abs() < 1e-12);
        // elapsed_s stays the pure transmission fold.
        assert!((round.elapsed_s - 2e-3).abs() < 1e-12);
        assert_eq!(round.critical_node, Some(1));
        // Totals are untouched: faults reschedule, they never change bytes.
        assert_eq!(r.total_bytes, 2000);
        assert_eq!(r.total_msgs, 2);
    }

    #[test]
    fn straggler_waits_only_once_the_clock_catches_up() {
        let mut net = BroadcastNet::homogeneous(2, 8e6, 0.0).unwrap();
        net.set_straggle(&[0.0, 5e-4]).unwrap();
        net.broadcast(0, 1000); // clock at 1 ms > ready[1]
        net.broadcast(1, 1000); // no wait
        let r = net.report();
        assert_eq!(r.straggler_delay_s, 0.0);
        assert_eq!(r.rounds[0].critical_node, None);
        assert!((r.elapsed_s - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn all_zero_straggle_is_the_identical_fault_free_fold() {
        let mk = |straggle: bool| {
            let mut net = BroadcastNet::new(vec![8e6, 2e6], 3e-4).unwrap();
            if straggle {
                net.set_straggle(&[0.0, 0.0]).unwrap();
            }
            net.begin_round();
            net.broadcast(0, 900);
            net.broadcast(1, 100);
            net.report()
        };
        assert_eq!(mk(true), mk(false));
    }

    #[test]
    fn straggler_delay_persists_across_batch_resets() {
        let mut net = BroadcastNet::homogeneous(2, 8e6, 0.0).unwrap();
        net.set_straggle(&[0.0, 5e-3]).unwrap();
        net.broadcast(1, 1000);
        let first = net.report();
        net.reset();
        net.broadcast(1, 1000);
        let second = net.report();
        assert_eq!(
            first.straggler_delay_s.to_bits(),
            second.straggler_delay_s.to_bits()
        );
        assert_eq!(first.elapsed_s.to_bits(), second.elapsed_s.to_bits());
    }

    #[test]
    fn straggler_holds_links_on_switched_topologies() {
        let mut net =
            BroadcastNet::with_topology(vec![8e6, 8e6], 0.0, Topology::Flat).unwrap();
        net.set_straggle(&[0.0, 5e-3]).unwrap();
        net.begin_round();
        net.begin_group(0b01);
        net.broadcast(0, 1000); // 0..1 ms
        net.begin_group(0b10);
        net.broadcast(1, 1000); // held to 5 ms, 5..6 ms
        let r = net.report();
        assert!((r.elapsed_s - 6e-3).abs() < 1e-12);
        assert!((r.straggler_delay_s - 5e-3).abs() < 1e-12);
        assert_eq!(r.rounds[0].critical_node, Some(1));
        assert_eq!(r.rounds[0].critical_group, Some(1));
    }

    #[test]
    fn bad_straggle_times_are_typed_errors() {
        let mut net = BroadcastNet::homogeneous(2, 8e6, 0.0).unwrap();
        assert!(net.set_straggle(&[0.0]).is_err());
        assert!(net.set_straggle(&[0.0, -1.0]).is_err());
        assert!(net.set_straggle(&[0.0, f64::NAN]).is_err());
        assert!(net.set_straggle(&[0.0, 1.0]).is_ok());
    }

    #[test]
    fn recovery_counters_meter_and_reset() {
        // 8 Mbit/s -> 1000 bytes = 1 ms; latency 0.1 ms.
        let mut net = BroadcastNet::homogeneous(2, 8e6, 1e-4).unwrap();
        net.begin_round();
        net.broadcast(0, 1000);
        net.note_erased();
        let plan_only = net.report();
        assert_eq!(plan_only.erased_broadcasts, 1);
        assert_eq!(plan_only.retransmit_rounds, 0);
        assert_eq!(plan_only.recovery_bytes, 0);

        net.begin_retransmit_round(1); // backoff = latency * 2^0
        net.retransmit_unicast(1, 1000);
        let r = net.report();
        assert_eq!(r.retransmit_rounds, 1);
        assert_eq!(r.recovery_bytes, 1000);
        assert_eq!(r.nack_rtts, 1);
        // Totals include the recovery unicast; the round partition holds.
        assert_eq!(r.total_bytes, 2000);
        assert_eq!(r.msgs_by_node, vec![1, 1]);
        assert_eq!(r.rounds.iter().map(|s| s.bytes).sum::<u64>(), r.total_bytes);
        // Clock: plan tx (1.1ms) + backoff (0.1ms) + NACK (0.1ms) + resend (1.1ms).
        assert!((r.elapsed_s - (1.1e-3 + 1e-4 + 1e-4 + 1.1e-3)).abs() < 1e-12);
        // elapsed_s of the round stays the pure transmission fold; the
        // waits land in makespan only.
        assert!((r.rounds[0].elapsed_s - 2.2e-3).abs() < 1e-12);
        assert!((r.rounds[0].makespan_s - r.elapsed_s).abs() < 1e-12);
        // Exponential backoff doubles per sweep.
        net.begin_retransmit_round(2);
        let r2 = net.report();
        assert!((r2.elapsed_s - (r.elapsed_s + 2e-4)).abs() < 1e-12);
        assert_eq!(r2.retransmit_rounds, 2);
        // All four counters are per-batch: reset zeroes them.
        net.reset();
        let clean = net.report();
        assert_eq!(clean.erased_broadcasts, 0);
        assert_eq!(clean.retransmit_rounds, 0);
        assert_eq!(clean.recovery_bytes, 0);
        assert_eq!(clean.nack_rtts, 0);
    }

    #[test]
    fn switched_reset_rewinds_the_schedule() {
        let mut net = BroadcastNet::with_topology(vec![8e6, 8e6], 0.0, Topology::Flat).unwrap();
        net.begin_round();
        net.begin_group(0b01);
        net.broadcast(0, 1000);
        let before = net.report();
        net.reset();
        net.begin_round();
        net.begin_group(0b01);
        net.broadcast(0, 1000);
        let after = net.report();
        assert_eq!(after.elapsed_s.to_bits(), before.elapsed_s.to_bits());
        assert_eq!(after.links, before.links);
        assert_eq!(after.epoch, before.epoch + 1);
    }
}
