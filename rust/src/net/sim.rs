//! Simulated broadcast network with heterogeneous uplinks.
//!
//! The paper's Shuffle phase is a sequence of broadcasts on a shared
//! medium (§II): each message from node `k` reaches all other nodes. The
//! simulator byte-accounts every broadcast exactly (this is the paper's
//! communication-load metric, measured rather than predicted) and advances
//! a virtual clock: node `k` transmits at `uplink_bps[k]`, transmissions
//! on the shared medium serialize, and each message pays a fixed `latency`
//! (the EC2-style per-message overhead that makes many small messages
//! slower than few large ones — why coded shuffle also wins wall-clock).
//!
//! This substitutes for the paper's EC2 testbed (DESIGN.md §4): the
//! load metric is exact; the time model preserves the who-wins ordering.

/// Shared-medium broadcast network simulator.
#[derive(Clone, Debug)]
pub struct BroadcastNet {
    /// Per-node uplink rate, bits/second.
    pub uplink_bps: Vec<f64>,
    /// Fixed per-message latency, seconds.
    pub latency_s: f64,
    bytes_by_node: Vec<u64>,
    msgs_by_node: Vec<u64>,
    clock_s: f64,
}

/// Byte-exact accounting of one phase.
#[derive(Clone, Debug, PartialEq)]
pub struct NetReport {
    pub bytes_by_node: Vec<u64>,
    pub msgs_by_node: Vec<u64>,
    pub total_bytes: u64,
    pub total_msgs: u64,
    /// Virtual wall-clock of the serialized broadcast schedule.
    pub elapsed_s: f64,
}

impl BroadcastNet {
    pub fn new(uplink_bps: Vec<f64>, latency_s: f64) -> Self {
        assert!(!uplink_bps.is_empty());
        assert!(uplink_bps.iter().all(|&b| b > 0.0));
        let k = uplink_bps.len();
        Self {
            uplink_bps,
            latency_s,
            bytes_by_node: vec![0; k],
            msgs_by_node: vec![0; k],
            clock_s: 0.0,
        }
    }

    /// Uniform-bandwidth convenience constructor.
    pub fn homogeneous(k: usize, uplink_bps: f64, latency_s: f64) -> Self {
        Self::new(vec![uplink_bps; k], latency_s)
    }

    /// Record one broadcast of `nbytes` from `sender`; returns its
    /// transmission time (s).
    pub fn broadcast(&mut self, sender: usize, nbytes: usize) -> f64 {
        self.bytes_by_node[sender] += nbytes as u64;
        self.msgs_by_node[sender] += 1;
        let t = self.latency_s + (nbytes as f64 * 8.0) / self.uplink_bps[sender];
        self.clock_s += t;
        t
    }

    pub fn report(&self) -> NetReport {
        NetReport {
            bytes_by_node: self.bytes_by_node.clone(),
            msgs_by_node: self.msgs_by_node.clone(),
            total_bytes: self.bytes_by_node.iter().sum(),
            total_msgs: self.msgs_by_node.iter().sum(),
            elapsed_s: self.clock_s,
        }
    }

    pub fn reset(&mut self) {
        self.bytes_by_node.iter_mut().for_each(|b| *b = 0);
        self.msgs_by_node.iter_mut().for_each(|m| *m = 0);
        self.clock_s = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounts_bytes_and_messages() {
        let mut net = BroadcastNet::homogeneous(3, 8e6, 0.0);
        net.broadcast(0, 1000);
        net.broadcast(0, 500);
        net.broadcast(2, 250);
        let r = net.report();
        assert_eq!(r.bytes_by_node, vec![1500, 0, 250]);
        assert_eq!(r.msgs_by_node, vec![2, 0, 1]);
        assert_eq!(r.total_bytes, 1750);
        assert_eq!(r.total_msgs, 3);
    }

    #[test]
    fn time_model_serializes_transmissions() {
        // 8 Mbit/s -> 1000 bytes = 1 ms; plus 0.1 ms latency each.
        let mut net = BroadcastNet::homogeneous(2, 8e6, 1e-4);
        net.broadcast(0, 1000);
        net.broadcast(1, 1000);
        let r = net.report();
        assert!((r.elapsed_s - (2.0 * (1e-3 + 1e-4))).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_uplinks_differ() {
        let mut net = BroadcastNet::new(vec![8e6, 4e6], 0.0);
        let t_fast = net.broadcast(0, 1000);
        let t_slow = net.broadcast(1, 1000);
        assert!((t_slow / t_fast - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reset_clears_state() {
        let mut net = BroadcastNet::homogeneous(2, 1e6, 0.0);
        net.broadcast(0, 10);
        net.reset();
        let r = net.report();
        assert_eq!(r.total_bytes, 0);
        assert_eq!(r.elapsed_s, 0.0);
    }
}
