//! Fault-injection specs: stragglers, broadcast-loss repair, dropout.
//!
//! [`FaultSpec`] names the faults a plan is built (and metered) under.
//! It is parsed from / rendered to the CLI `--faults` spec string the
//! same way [`crate::net::Topology`] handles `--topology`: a canonical
//! string that feeds cache keys and plan fingerprints, omitted from
//! serialized cluster JSON when no fault is configured so every
//! fault-free artifact stays byte-identical to the pre-fault era.
//!
//! Two orthogonal clauses:
//!
//! - `straggle:seed=S,amp=A` — deterministic per-node compute-rate
//!   jitter. Node `i` Maps `slowdown(i) = 1 + A·u_i` times slower than
//!   its nominal rate, where `u_i ∈ [0,1)` is drawn from a fixed-seed
//!   generator keyed by `(S, i)` alone — independent of K, batch, or
//!   thread count, so every executor mode sees the same jitter. The
//!   slowdown delays the node's *sends* in the shuffle (it joins the
//!   schedule late); metering stays one plan-order pass, see
//!   [`crate::net::sim`].
//! - `repair:f=N` — degraded-decode mode: the plan must tolerate any
//!   `N` lost broadcasts. The coder's shuffle IR gains appended repair
//!   rounds and the worklist decoder proves every loss pattern up to
//!   `N` still recovers all IVs at build time, see
//!   [`crate::coding::plan::with_repair_rounds`].
//!
//! Dropout (a node lost *after* planning) is not a spec clause: it is
//! handled by re-planning, see `Plan::replan_without`.

use crate::error::{HetcdcError, Result};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

fn invalid(msg: impl Into<String>) -> HetcdcError {
    HetcdcError::InvalidParams(msg.into())
}

/// Largest supported loss tolerance: build-time verification enumerates
/// every loss pattern of up to `f` broadcasts, which is combinatorial.
pub const MAX_REPAIR_F: usize = 2;

/// Deterministic per-node compute-rate jitter (the straggler model).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Straggle {
    /// Seed of the per-node jitter stream.
    pub seed: u64,
    /// Jitter amplitude: node `i` is slowed by a factor in `[1, 1+amp)`.
    pub amp: f64,
}

/// Fault model a plan is built and metered under. `FaultSpec::default()`
/// (no faults) is the implicit state of every pre-fault artifact.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Straggler jitter; `None` = every node Maps at its nominal rate.
    pub straggle: Option<Straggle>,
    /// Tolerated lost broadcasts (degraded decode); 0 = none.
    pub repair: usize,
}

impl FaultSpec {
    /// True when no fault is configured (the default everywhere).
    pub fn is_none(&self) -> bool {
        self.straggle.is_none() && self.repair == 0
    }

    /// Parse a CLI/JSON spec string: `;`-separated clauses out of
    /// `straggle:seed=S,amp=A` and `repair:f=N` (`none` for the empty
    /// spec). Seeds accept decimal or `0x` hex.
    pub fn parse(spec: &str) -> Result<FaultSpec> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultSpec::default());
        }
        let mut out = FaultSpec::default();
        for clause in spec.split(';').filter(|c| !c.trim().is_empty()) {
            let clause = clause.trim();
            let (head, body) = clause
                .split_once(':')
                .ok_or_else(|| invalid(format!("unknown fault clause '{clause}'")))?;
            match head.trim() {
                "straggle" => {
                    if out.straggle.is_some() {
                        return Err(invalid("duplicate straggle clause"));
                    }
                    out.straggle = Some(parse_straggle(body)?);
                }
                "repair" => {
                    if out.repair != 0 {
                        return Err(invalid("duplicate repair clause"));
                    }
                    out.repair = parse_repair(body)?;
                }
                h => return Err(invalid(format!("unknown fault clause '{h}'"))),
            }
        }
        Ok(out)
    }

    /// Canonical spec string: `parse(spec()) == self`, and equal specs
    /// render equal strings (used in cache keys and fingerprints).
    /// The empty spec renders as `none`.
    pub fn spec(&self) -> String {
        let mut clauses = Vec::new();
        if let Some(s) = &self.straggle {
            clauses.push(format!("straggle:seed={:#x},amp={}", s.seed, s.amp));
        }
        if self.repair != 0 {
            clauses.push(format!("repair:f={}", self.repair));
        }
        if clauses.is_empty() {
            "none".into()
        } else {
            clauses.join(";")
        }
    }

    /// Validate against a cluster of `k` nodes.
    pub fn validate(&self, _k: usize) -> Result<()> {
        if let Some(s) = &self.straggle {
            if !(s.amp.is_finite() && s.amp >= 0.0) {
                return Err(invalid(format!(
                    "straggle amplitude must be finite and >= 0, got {}",
                    s.amp
                )));
            }
        }
        if self.repair > MAX_REPAIR_F {
            return Err(invalid(format!(
                "repair f={} exceeds the supported maximum {MAX_REPAIR_F} \
                 (loss-pattern verification is combinatorial in f)",
                self.repair
            )));
        }
        Ok(())
    }

    /// Per-node Map slowdown factors (>= 1), length `k`. Node `i`'s
    /// factor depends only on `(seed, i)`: stable under K growth, batch
    /// index, and thread count. All ones when no straggle is configured.
    pub fn slowdowns(&self, k: usize) -> Vec<f64> {
        match &self.straggle {
            None => vec![1.0; k],
            Some(s) => (0..k)
                .map(|i| {
                    let node_seed =
                        s.seed.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    1.0 + s.amp * Xoshiro256::seed_from_u64(node_seed).f64_unit()
                })
                .collect(),
        }
    }

    /// JSON form used inside serialized cluster specs (the spec string).
    pub fn to_json(&self) -> Json {
        Json::Str(self.spec())
    }

    pub fn from_json(j: &Json) -> Result<FaultSpec> {
        j.as_str()
            .ok_or_else(|| HetcdcError::Json("faults must be a spec string".into()))
            .and_then(FaultSpec::parse)
    }
}

fn parse_straggle(body: &str) -> Result<Straggle> {
    let mut seed: Option<u64> = None;
    let mut amp: Option<f64> = None;
    for pair in body.split(',').filter(|p| !p.trim().is_empty()) {
        let (key, val) = pair
            .split_once('=')
            .ok_or_else(|| invalid(format!("straggle option '{pair}' is not key=value")))?;
        match (key.trim(), val.trim()) {
            ("seed", v) => {
                let parsed = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
                    Some(hex) => u64::from_str_radix(hex, 16),
                    None => v.parse::<u64>(),
                };
                seed = Some(parsed.map_err(|_| {
                    invalid(format!("straggle seed '{v}' is not an integer"))
                })?);
            }
            ("amp", v) => {
                amp = Some(v.parse::<f64>().map_err(|_| {
                    invalid(format!("straggle amplitude '{v}' is not a number"))
                })?);
            }
            (k, _) => return Err(invalid(format!("unknown straggle option '{k}'"))),
        }
    }
    Ok(Straggle {
        seed: seed.ok_or_else(|| invalid("straggle needs seed=<int>"))?,
        amp: amp.ok_or_else(|| invalid("straggle needs amp=<number>"))?,
    })
}

fn parse_repair(body: &str) -> Result<usize> {
    let mut f: Option<usize> = None;
    for pair in body.split(',').filter(|p| !p.trim().is_empty()) {
        let (key, val) = pair
            .split_once('=')
            .ok_or_else(|| invalid(format!("repair option '{pair}' is not key=value")))?;
        match (key.trim(), val.trim()) {
            ("f", v) => {
                f = Some(v.parse::<usize>().map_err(|_| {
                    invalid(format!("repair tolerance '{v}' is not an integer"))
                })?);
            }
            (k, _) => return Err(invalid(format!("unknown repair option '{k}'"))),
        }
    }
    let f = f.ok_or_else(|| invalid("repair needs f=<int>"))?;
    if f == 0 {
        return Err(invalid("repair f must be >= 1 (omit the clause for none)"));
    }
    Ok(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_spec_roundtrip() {
        for spec in [
            "none",
            "straggle:seed=0xbe7c,amp=0.5",
            "repair:f=1",
            "straggle:seed=0x7,amp=0.25;repair:f=2",
        ] {
            let f = FaultSpec::parse(spec).unwrap();
            assert_eq!(f.spec(), spec);
            assert_eq!(FaultSpec::parse(&f.spec()).unwrap(), f);
        }
        // Decimal seeds canonicalize to hex.
        let f = FaultSpec::parse("straggle:seed=16,amp=1").unwrap();
        assert_eq!(f.spec(), "straggle:seed=0x10,amp=1");
        assert!(FaultSpec::parse("").unwrap().is_none());
        assert!(FaultSpec::parse("none").unwrap().is_none());
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        for bad in [
            "jitter",
            "straggle",
            "straggle:amp=0.5",
            "straggle:seed=0x1",
            "straggle:seed=zz,amp=0.5",
            "straggle:seed=1,amp=fast",
            "straggle:seed=1,amp=0.5,extra=1",
            "repair:f=0",
            "repair:f=one",
            "repair:g=1",
            "straggle:seed=1,amp=0.5;straggle:seed=2,amp=0.5",
            "repair:f=1;repair:f=2",
        ] {
            assert!(
                matches!(FaultSpec::parse(bad), Err(HetcdcError::InvalidParams(_))),
                "{bad}"
            );
        }
    }

    #[test]
    fn validate_rejects_bad_values() {
        let mut f = FaultSpec::parse("straggle:seed=1,amp=0.5").unwrap();
        assert!(f.validate(4).is_ok());
        f.straggle = Some(Straggle { seed: 1, amp: -0.5 });
        assert!(f.validate(4).is_err());
        f.straggle = Some(Straggle { seed: 1, amp: f64::NAN });
        assert!(f.validate(4).is_err());
        let f = FaultSpec { straggle: None, repair: MAX_REPAIR_F + 1 };
        assert!(f.validate(4).is_err());
        assert!(FaultSpec { straggle: None, repair: MAX_REPAIR_F }.validate(4).is_ok());
    }

    #[test]
    fn slowdowns_are_deterministic_and_prefix_stable() {
        let f = FaultSpec::parse("straggle:seed=0xbe7c,amp=0.5").unwrap();
        let a = f.slowdowns(4);
        let b = f.slowdowns(4);
        assert_eq!(a, b);
        // Node i's factor does not change when the cluster grows.
        let wide = f.slowdowns(8);
        assert_eq!(&wide[..4], &a[..]);
        for &s in &wide {
            assert!((1.0..1.5).contains(&s), "{s}");
        }
        // No straggle => exactly 1.0 everywhere.
        assert_eq!(FaultSpec::default().slowdowns(3), vec![1.0; 3]);
    }

    #[test]
    fn different_seeds_differ() {
        let a = FaultSpec::parse("straggle:seed=1,amp=0.5").unwrap().slowdowns(6);
        let b = FaultSpec::parse("straggle:seed=2,amp=0.5").unwrap().slowdowns(6);
        assert_ne!(a, b);
    }

    #[test]
    fn json_roundtrip() {
        let f = FaultSpec::parse("straggle:seed=0x5,amp=0.75;repair:f=1").unwrap();
        assert_eq!(FaultSpec::from_json(&f.to_json()).unwrap(), f);
        assert!(FaultSpec::from_json(&Json::Num(1.0)).is_err());
    }
}
