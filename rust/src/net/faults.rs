//! Fault-injection specs: stragglers, broadcast-loss repair, dropout.
//!
//! [`FaultSpec`] names the faults a plan is built (and metered) under.
//! It is parsed from / rendered to the CLI `--faults` spec string the
//! same way [`crate::net::Topology`] handles `--topology`: a canonical
//! string that feeds cache keys and plan fingerprints, omitted from
//! serialized cluster JSON when no fault is configured so every
//! fault-free artifact stays byte-identical to the pre-fault era.
//!
//! Four orthogonal clauses:
//!
//! - `straggle:seed=S,amp=A` — deterministic per-node compute-rate
//!   jitter. Node `i` Maps `slowdown(i) = 1 + A·u_i` times slower than
//!   its nominal rate, where `u_i ∈ [0,1)` is drawn from a fixed-seed
//!   generator keyed by `(S, i)` alone — independent of K, batch, or
//!   thread count, so every executor mode sees the same jitter. The
//!   slowdown delays the node's *sends* in the shuffle (it joins the
//!   schedule late); metering stays one plan-order pass, see
//!   [`crate::net::sim`].
//! - `repair:f=N` — degraded-decode mode: the plan must tolerate any
//!   `N` lost broadcasts. The coder's shuffle IR gains appended repair
//!   rounds and the worklist decoder proves every loss pattern up to
//!   `N` still recovers all IVs at build time, see
//!   [`crate::coding::plan::with_repair_rounds`].
//! - `erase:seed=S,p=P` (or the targeted `erase:list=r.g.b,...` form) —
//!   runtime broadcast erasure: a shuffle multicast is transmitted and
//!   metered but reaches *no* receiver. The seeded form erases each
//!   broadcast independently with probability `p`, keyed by
//!   `(S, batch-epoch, round, group, broadcast-in-group)` alone — like
//!   straggler jitter, the outcome never depends on thread count or
//!   execution mode. The executor decodes from the survivors (repair
//!   rounds absorb what they can) and recovers still-stranded IVs via
//!   deterministic unicast retransmission, see [`crate::engine::exec`].
//! - `drop:node=i,at_batch=b` — mid-run dropout: node `i` is lost once
//!   `b` batches have completed. The executor finishes in-flight work,
//!   re-plans without the node (`Plan::replan_without`), and resumes the
//!   remaining batches on the survivor plan.

use crate::error::{HetcdcError, Result};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

fn invalid(msg: impl Into<String>) -> HetcdcError {
    HetcdcError::InvalidParams(msg.into())
}

/// Largest supported loss tolerance: build-time verification enumerates
/// every loss pattern of up to `f` broadcasts, which is combinatorial.
pub const MAX_REPAIR_F: usize = 2;

/// Deterministic per-node compute-rate jitter (the straggler model).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Straggle {
    /// Seed of the per-node jitter stream.
    pub seed: u64,
    /// Jitter amplitude: node `i` is slowed by a factor in `[1, 1+amp)`.
    pub amp: f64,
}

/// Deterministic runtime broadcast-erasure model: which shuffle
/// multicasts are transmitted but received by nobody.
#[derive(Clone, Debug, PartialEq)]
pub enum Erase {
    /// Erase each broadcast independently with probability `p`, keyed by
    /// `(seed, batch-epoch, round, group, broadcast-in-group)` alone —
    /// thread- and mode-invariant by construction.
    Seeded { seed: u64, p: f64 },
    /// Targeted test form: erase exactly the listed
    /// `(round, group, broadcast-in-group)` coordinates, every batch.
    /// Canonically sorted and deduplicated.
    List(Vec<(usize, usize, usize)>),
}

impl Erase {
    /// Whether the broadcast at `(round, group, b)` of batch `epoch` is
    /// erased. Pure function of the spec and the coordinates: every
    /// execution mode, thread count, and replay answers identically.
    pub fn erased(&self, epoch: u64, round: usize, group: usize, b: usize) -> bool {
        match self {
            Erase::Seeded { seed, p } => {
                // Distinct odd mixing constants per coordinate, so no two
                // coordinates alias (same keying idiom as `slowdowns`).
                let key = seed
                    .wrapping_add(epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                    .wrapping_add((round as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
                    .wrapping_add((group as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
                    .wrapping_add((b as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93));
                Xoshiro256::seed_from_u64(key).f64_unit() < *p
            }
            Erase::List(list) => list.binary_search(&(round, group, b)).is_ok(),
        }
    }
}

/// Mid-run node dropout: `node` is lost once `at_batch` batches have
/// completed; the remaining batches run on a survivor re-plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dropout {
    /// Node index that drops out.
    pub node: usize,
    /// Global batch index at which it drops (0 = before the first batch).
    pub at_batch: u64,
}

/// Fault model a plan is built and metered under. `FaultSpec::default()`
/// (no faults) is the implicit state of every pre-fault artifact.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Straggler jitter; `None` = every node Maps at its nominal rate.
    pub straggle: Option<Straggle>,
    /// Tolerated lost broadcasts (degraded decode); 0 = none.
    pub repair: usize,
    /// Runtime broadcast erasures; `None` = every broadcast lands.
    pub erase: Option<Erase>,
    /// Mid-run node dropout; `None` = every node survives the run.
    pub dropout: Option<Dropout>,
}

impl FaultSpec {
    /// True when no fault is configured (the default everywhere).
    pub fn is_none(&self) -> bool {
        self.straggle.is_none()
            && self.repair == 0
            && self.erase.is_none()
            && self.dropout.is_none()
    }

    /// Parse a CLI/JSON spec string: `;`-separated clauses out of
    /// `straggle:seed=S,amp=A`, `repair:f=N`, `erase:seed=S,p=P` /
    /// `erase:list=r.g.b,...`, and `drop:node=i,at_batch=b` (`none` for
    /// the empty spec). Seeds accept decimal or `0x` hex. At most one
    /// clause of each kind.
    pub fn parse(spec: &str) -> Result<FaultSpec> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultSpec::default());
        }
        let mut out = FaultSpec::default();
        for clause in spec.split(';').filter(|c| !c.trim().is_empty()) {
            let clause = clause.trim();
            let (head, body) = clause
                .split_once(':')
                .ok_or_else(|| invalid(format!("unknown fault clause '{clause}'")))?;
            match head.trim() {
                "straggle" => {
                    if out.straggle.is_some() {
                        return Err(invalid("duplicate straggle clause"));
                    }
                    out.straggle = Some(parse_straggle(body)?);
                }
                "repair" => {
                    if out.repair != 0 {
                        return Err(invalid("duplicate repair clause"));
                    }
                    out.repair = parse_repair(body)?;
                }
                "erase" => {
                    if out.erase.is_some() {
                        return Err(invalid("duplicate erase clause (at most one)"));
                    }
                    out.erase = Some(parse_erase(body)?);
                }
                "drop" => {
                    if out.dropout.is_some() {
                        return Err(invalid("duplicate drop clause"));
                    }
                    out.dropout = Some(parse_drop(body)?);
                }
                h => return Err(invalid(format!("unknown fault clause '{h}'"))),
            }
        }
        Ok(out)
    }

    /// Canonical spec string: `parse(spec()) == self`, and equal specs
    /// render equal strings (used in cache keys and fingerprints).
    /// The empty spec renders as `none`.
    pub fn spec(&self) -> String {
        let mut clauses = Vec::new();
        if let Some(s) = &self.straggle {
            clauses.push(format!("straggle:seed={:#x},amp={}", s.seed, s.amp));
        }
        if self.repair != 0 {
            clauses.push(format!("repair:f={}", self.repair));
        }
        match &self.erase {
            Some(Erase::Seeded { seed, p }) => {
                clauses.push(format!("erase:seed={seed:#x},p={p}"));
            }
            Some(Erase::List(list)) => {
                let entries: Vec<String> = list
                    .iter()
                    .map(|&(r, g, b)| format!("{r}.{g}.{b}"))
                    .collect();
                clauses.push(format!("erase:list={}", entries.join(",")));
            }
            None => {}
        }
        if let Some(d) = &self.dropout {
            clauses.push(format!("drop:node={},at_batch={}", d.node, d.at_batch));
        }
        if clauses.is_empty() {
            "none".into()
        } else {
            clauses.join(";")
        }
    }

    /// Validate against a cluster of `k` nodes.
    pub fn validate(&self, k: usize) -> Result<()> {
        if let Some(s) = &self.straggle {
            if !(s.amp.is_finite() && s.amp >= 0.0) {
                return Err(invalid(format!(
                    "straggle amplitude must be finite and >= 0, got {}",
                    s.amp
                )));
            }
        }
        if self.repair > MAX_REPAIR_F {
            return Err(invalid(format!(
                "repair f={} exceeds the supported maximum {MAX_REPAIR_F} \
                 (loss-pattern verification is combinatorial in f)",
                self.repair
            )));
        }
        match &self.erase {
            Some(Erase::Seeded { p, .. }) => {
                if !(p.is_finite() && *p > 0.0 && *p <= 1.0) {
                    return Err(invalid(format!(
                        "erase probability must satisfy 0 < p <= 1, got {p}"
                    )));
                }
            }
            Some(Erase::List(list)) => {
                if list.is_empty() {
                    return Err(invalid("erase list must name at least one broadcast"));
                }
                if !list.windows(2).all(|w| w[0] < w[1]) {
                    return Err(invalid(
                        "erase list must be sorted and deduplicated \
                         (parse canonicalizes; construct sorted)",
                    ));
                }
            }
            None => {}
        }
        if let Some(d) = &self.dropout {
            if d.node >= k {
                return Err(invalid(format!(
                    "drop node {} out of range for a {k}-node cluster",
                    d.node
                )));
            }
        }
        Ok(())
    }

    /// Per-node Map slowdown factors (>= 1), length `k`. Node `i`'s
    /// factor depends only on `(seed, i)`: stable under K growth, batch
    /// index, and thread count. All ones when no straggle is configured.
    pub fn slowdowns(&self, k: usize) -> Vec<f64> {
        match &self.straggle {
            None => vec![1.0; k],
            Some(s) => (0..k)
                .map(|i| {
                    let node_seed =
                        s.seed.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    1.0 + s.amp * Xoshiro256::seed_from_u64(node_seed).f64_unit()
                })
                .collect(),
        }
    }

    /// JSON form used inside serialized cluster specs (the spec string).
    pub fn to_json(&self) -> Json {
        Json::Str(self.spec())
    }

    pub fn from_json(j: &Json) -> Result<FaultSpec> {
        j.as_str()
            .ok_or_else(|| HetcdcError::Json("faults must be a spec string".into()))
            .and_then(FaultSpec::parse)
    }
}

fn parse_straggle(body: &str) -> Result<Straggle> {
    let mut seed: Option<u64> = None;
    let mut amp: Option<f64> = None;
    for pair in body.split(',').filter(|p| !p.trim().is_empty()) {
        let (key, val) = pair
            .split_once('=')
            .ok_or_else(|| invalid(format!("straggle option '{pair}' is not key=value")))?;
        match (key.trim(), val.trim()) {
            ("seed", v) => {
                let parsed = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
                    Some(hex) => u64::from_str_radix(hex, 16),
                    None => v.parse::<u64>(),
                };
                seed = Some(parsed.map_err(|_| {
                    invalid(format!("straggle seed '{v}' is not an integer"))
                })?);
            }
            ("amp", v) => {
                amp = Some(v.parse::<f64>().map_err(|_| {
                    invalid(format!("straggle amplitude '{v}' is not a number"))
                })?);
            }
            (k, _) => return Err(invalid(format!("unknown straggle option '{k}'"))),
        }
    }
    Ok(Straggle {
        seed: seed.ok_or_else(|| invalid("straggle needs seed=<int>"))?,
        amp: amp.ok_or_else(|| invalid("straggle needs amp=<number>"))?,
    })
}

fn parse_repair(body: &str) -> Result<usize> {
    let mut f: Option<usize> = None;
    for pair in body.split(',').filter(|p| !p.trim().is_empty()) {
        let (key, val) = pair
            .split_once('=')
            .ok_or_else(|| invalid(format!("repair option '{pair}' is not key=value")))?;
        match (key.trim(), val.trim()) {
            ("f", v) => {
                f = Some(v.parse::<usize>().map_err(|_| {
                    invalid(format!("repair tolerance '{v}' is not an integer"))
                })?);
            }
            (k, _) => return Err(invalid(format!("unknown repair option '{k}'"))),
        }
    }
    let f = f.ok_or_else(|| invalid("repair needs f=<int>"))?;
    if f == 0 {
        return Err(invalid("repair f must be >= 1 (omit the clause for none)"));
    }
    Ok(f)
}

fn parse_u64(v: &str, what: &str) -> Result<u64> {
    let parsed = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => v.parse::<u64>(),
    };
    parsed.map_err(|_| invalid(format!("{what} '{v}' is not an integer")))
}

fn parse_erase(body: &str) -> Result<Erase> {
    if let Some(list) = body.trim().strip_prefix("list=") {
        let mut entries = Vec::new();
        for entry in list.split(',').filter(|e| !e.trim().is_empty()) {
            let parts: Vec<&str> = entry.trim().split('.').collect();
            let coords: Option<Vec<usize>> = if parts.len() == 3 {
                parts.iter().map(|p| p.parse::<usize>().ok()).collect()
            } else {
                None
            };
            match coords {
                Some(c) => entries.push((c[0], c[1], c[2])),
                None => {
                    return Err(invalid(format!(
                        "erase list entry '{entry}' is not round.group.broadcast"
                    )))
                }
            }
        }
        if entries.is_empty() {
            return Err(invalid("erase list must name at least one broadcast"));
        }
        entries.sort_unstable();
        entries.dedup();
        return Ok(Erase::List(entries));
    }
    let mut seed: Option<u64> = None;
    let mut p: Option<f64> = None;
    for pair in body.split(',').filter(|p| !p.trim().is_empty()) {
        let (key, val) = pair
            .split_once('=')
            .ok_or_else(|| invalid(format!("erase option '{pair}' is not key=value")))?;
        match (key.trim(), val.trim()) {
            ("seed", v) => seed = Some(parse_u64(v, "erase seed")?),
            ("p", v) => {
                p = Some(v.parse::<f64>().map_err(|_| {
                    invalid(format!("erase probability '{v}' is not a number"))
                })?);
            }
            (k, _) => return Err(invalid(format!("unknown erase option '{k}'"))),
        }
    }
    Ok(Erase::Seeded {
        seed: seed.ok_or_else(|| invalid("erase needs seed=<int> (or list=...)"))?,
        p: p.ok_or_else(|| invalid("erase needs p=<probability>"))?,
    })
}

fn parse_drop(body: &str) -> Result<Dropout> {
    let mut node: Option<usize> = None;
    let mut at_batch: Option<u64> = None;
    for pair in body.split(',').filter(|p| !p.trim().is_empty()) {
        let (key, val) = pair
            .split_once('=')
            .ok_or_else(|| invalid(format!("drop option '{pair}' is not key=value")))?;
        match (key.trim(), val.trim()) {
            ("node", v) => {
                node = Some(v.parse::<usize>().map_err(|_| {
                    invalid(format!("drop node '{v}' is not an integer"))
                })?);
            }
            ("at_batch", v) => at_batch = Some(parse_u64(v, "drop at_batch")?),
            (k, _) => return Err(invalid(format!("unknown drop option '{k}'"))),
        }
    }
    Ok(Dropout {
        node: node.ok_or_else(|| invalid("drop needs node=<index>"))?,
        at_batch: at_batch.ok_or_else(|| invalid("drop needs at_batch=<int>"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_spec_roundtrip() {
        for spec in [
            "none",
            "straggle:seed=0xbe7c,amp=0.5",
            "repair:f=1",
            "straggle:seed=0x7,amp=0.25;repair:f=2",
            "erase:seed=0x5eed,p=0.05",
            "erase:list=0.1.2,1.0.0",
            "drop:node=2,at_batch=3",
            "straggle:seed=0x7,amp=0.25;repair:f=1;erase:seed=0x1,p=0.5;drop:node=0,at_batch=1",
        ] {
            let f = FaultSpec::parse(spec).unwrap();
            assert_eq!(f.spec(), spec);
            assert_eq!(FaultSpec::parse(&f.spec()).unwrap(), f);
        }
        // Decimal seeds canonicalize to hex.
        let f = FaultSpec::parse("straggle:seed=16,amp=1").unwrap();
        assert_eq!(f.spec(), "straggle:seed=0x10,amp=1");
        // Erase lists canonicalize sorted and deduplicated.
        let f = FaultSpec::parse("erase:list=1.0.0,0.1.2,1.0.0").unwrap();
        assert_eq!(f.spec(), "erase:list=0.1.2,1.0.0");
        assert!(FaultSpec::parse("").unwrap().is_none());
        assert!(FaultSpec::parse("none").unwrap().is_none());
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        for bad in [
            "jitter",
            "straggle",
            "straggle:amp=0.5",
            "straggle:seed=0x1",
            "straggle:seed=zz,amp=0.5",
            "straggle:seed=1,amp=fast",
            "straggle:seed=1,amp=0.5,extra=1",
            "repair:f=0",
            "repair:f=one",
            "repair:g=1",
            "straggle:seed=1,amp=0.5;straggle:seed=2,amp=0.5",
            "repair:f=1;repair:f=2",
            "erase:p=0.5",
            "erase:seed=0x1",
            "erase:seed=zz,p=0.5",
            "erase:seed=1,p=fast",
            "erase:seed=1,p=0.5,extra=1",
            "erase:list=",
            "erase:list=1.2",
            "erase:list=1.2.3.4",
            "erase:list=a.b.c",
            "erase:seed=1,p=0.5;erase:list=0.0.0",
            "drop:node=1",
            "drop:at_batch=2",
            "drop:node=x,at_batch=2",
            "drop:node=1,at_batch=2;drop:node=2,at_batch=3",
        ] {
            assert!(
                matches!(FaultSpec::parse(bad), Err(HetcdcError::InvalidParams(_))),
                "{bad}"
            );
        }
    }

    #[test]
    fn validate_rejects_bad_values() {
        let mut f = FaultSpec::parse("straggle:seed=1,amp=0.5").unwrap();
        assert!(f.validate(4).is_ok());
        f.straggle = Some(Straggle { seed: 1, amp: -0.5 });
        assert!(f.validate(4).is_err());
        f.straggle = Some(Straggle { seed: 1, amp: f64::NAN });
        assert!(f.validate(4).is_err());
        let f = FaultSpec { repair: MAX_REPAIR_F + 1, ..FaultSpec::default() };
        assert!(f.validate(4).is_err());
        let f = FaultSpec { repair: MAX_REPAIR_F, ..FaultSpec::default() };
        assert!(f.validate(4).is_ok());
        // Erase probability must lie in (0, 1].
        for p in [0.0, -0.5, 1.5, f64::NAN, f64::INFINITY] {
            let f = FaultSpec {
                erase: Some(Erase::Seeded { seed: 1, p }),
                ..FaultSpec::default()
            };
            assert!(f.validate(4).is_err(), "p={p}");
        }
        let f = FaultSpec {
            erase: Some(Erase::Seeded { seed: 1, p: 1.0 }),
            ..FaultSpec::default()
        };
        assert!(f.validate(4).is_ok());
        // Hand-built erase lists must already be canonical.
        let f = FaultSpec {
            erase: Some(Erase::List(vec![(1, 0, 0), (0, 1, 2)])),
            ..FaultSpec::default()
        };
        assert!(f.validate(4).is_err());
        let f = FaultSpec {
            erase: Some(Erase::List(vec![(0, 1, 2), (1, 0, 0)])),
            ..FaultSpec::default()
        };
        assert!(f.validate(4).is_ok());
        // Drop node must exist in the cluster.
        let f = FaultSpec {
            dropout: Some(Dropout { node: 4, at_batch: 0 }),
            ..FaultSpec::default()
        };
        assert!(f.validate(4).is_err());
        assert!(f.validate(5).is_ok());
    }

    #[test]
    fn erasure_draws_are_deterministic_and_coordinate_keyed() {
        let e = Erase::Seeded { seed: 0x5EED, p: 0.5 };
        // Pure function of the coordinates: identical on every call.
        for epoch in 0..4u64 {
            for r in 0..3 {
                for g in 0..3 {
                    for b in 0..3 {
                        assert_eq!(
                            e.erased(epoch, r, g, b),
                            e.erased(epoch, r, g, b)
                        );
                    }
                }
            }
        }
        // At p=0.5 over 256 coordinates, both outcomes must occur, and
        // the pattern must vary across epochs and seeds.
        let draws = |e: &Erase, epoch: u64| -> Vec<bool> {
            (0..256).map(|i| e.erased(epoch, i / 64, (i / 8) % 8, i % 8)).collect()
        };
        let a = draws(&e, 0);
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x));
        assert_ne!(a, draws(&e, 1), "epoch must re-key the draws");
        let other = Erase::Seeded { seed: 0x5EEE, p: 0.5 };
        assert_ne!(a, draws(&other, 0), "seed must re-key the draws");
        // p=1 erases everything.
        let all = Erase::Seeded { seed: 9, p: 1.0 };
        assert!(draws(&all, 3).iter().all(|&x| x));
    }

    #[test]
    fn erase_list_matches_exact_coordinates() {
        let e = Erase::List(vec![(0, 1, 2), (2, 0, 0)]);
        for epoch in 0..3u64 {
            assert!(e.erased(epoch, 0, 1, 2));
            assert!(e.erased(epoch, 2, 0, 0));
            assert!(!e.erased(epoch, 0, 1, 1));
            assert!(!e.erased(epoch, 1, 1, 2));
        }
    }

    #[test]
    fn slowdowns_are_deterministic_and_prefix_stable() {
        let f = FaultSpec::parse("straggle:seed=0xbe7c,amp=0.5").unwrap();
        let a = f.slowdowns(4);
        let b = f.slowdowns(4);
        assert_eq!(a, b);
        // Node i's factor does not change when the cluster grows.
        let wide = f.slowdowns(8);
        assert_eq!(&wide[..4], &a[..]);
        for &s in &wide {
            assert!((1.0..1.5).contains(&s), "{s}");
        }
        // No straggle => exactly 1.0 everywhere.
        assert_eq!(FaultSpec::default().slowdowns(3), vec![1.0; 3]);
    }

    #[test]
    fn different_seeds_differ() {
        let a = FaultSpec::parse("straggle:seed=1,amp=0.5").unwrap().slowdowns(6);
        let b = FaultSpec::parse("straggle:seed=2,amp=0.5").unwrap().slowdowns(6);
        assert_ne!(a, b);
    }

    #[test]
    fn json_roundtrip() {
        for spec in [
            "straggle:seed=0x5,amp=0.75;repair:f=1",
            "erase:seed=0x5eed,p=0.05;drop:node=1,at_batch=2",
            "erase:list=0.0.0,1.2.3",
        ] {
            let f = FaultSpec::parse(spec).unwrap();
            assert_eq!(FaultSpec::from_json(&f.to_json()).unwrap(), f);
        }
        assert!(FaultSpec::from_json(&Json::Num(1.0)).is_err());
    }
}
