//! Network substrate: simulated heterogeneous broadcast medium and
//! switched-topology variants.

pub mod sim;
pub mod topology;

pub use sim::{BroadcastNet, LinkLedger, NetReport, PhaseLedger, RoundLedger};
pub use topology::{LinkTable, Topology};
