//! Network substrate: simulated heterogeneous broadcast medium,
//! switched-topology variants, and fault-injection specs.

pub mod faults;
pub mod sim;
pub mod topology;

pub use faults::{Dropout, Erase, FaultSpec, Straggle};
pub use sim::{BroadcastNet, LinkLedger, NetReport, PhaseLedger, RoundLedger};
pub use topology::{LinkTable, Topology};
