//! Network substrate: simulated heterogeneous broadcast medium.

pub mod sim;

pub use sim::{BroadcastNet, NetReport, PhaseLedger, RoundLedger};
