//! Parallel plan-build determinism — the acceptance gate of the threaded
//! `JobBuilder` path: for every placer × coder pair that builds at
//! K ∈ {3, 5, 8, 12}, the serialized Plan JSON (schema v2) must be
//! **byte-identical** across `--threads ∈ {1, 2, 8}` (and auto), and the
//! sharded simplex pricing must return the same objective, values, and
//! pivot walk as the unsharded solve on the §V LPs.
//!
//! Threading a plan build may only change wall-clock: the LP enumeration
//! merges prefix shards in DFS order, the pricing scan takes the lowest
//! qualifying column regardless of chunking, the grid coder's groups and
//! rounds are pure functions of their indices, and the decode-schedule
//! verification shards by node — so not one byte of the artifact may
//! move. The K=8 shape now includes the §V LP via the exact path (cyclic
//! shift-orbit seeding keeps the master small); K=12 still uses the
//! non-enumerating grid placer only — the exact K=12 solve is bench
//! territory, not debug-mode test territory.

use hetcdc::engine::JobBuilder;
use hetcdc::lp::{solve, solve_with_threads};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::placement::lp_general::{build_lp, DEFAULT_COLLECTION_CAP};
use hetcdc::theory::params::ParamsK;

fn cluster(storage: &[u64]) -> ClusterSpec {
    let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
    for (node, &m) in c.nodes.iter_mut().zip(storage) {
        node.storage = m;
    }
    for (i, node) in c.nodes.iter_mut().enumerate() {
        node.uplink_mbps = 400.0 + 175.0 * (i % 3) as f64;
        node.map_files_per_s = 100.0 * (1 + i % 4) as f64;
    }
    c
}

fn small_job(n: u64) -> JobSpec {
    let mut job = JobSpec::terasort(n);
    job.t = 8;
    job.keys_per_file = 16;
    job
}

/// (storage, N, placers to try) per K. Placer and coder names that
/// reject a shape are skipped — the success floor at the end keeps the
/// sweep from going vacuous. K=12 runs the grid placer only: the
/// oblivious memory-sharing placement at this shape subpacketizes to
/// sp=165 (~2000 subfiles), which is bench territory, not debug-mode
/// test territory.
#[rustfmt::skip]
fn shapes() -> Vec<(Vec<u64>, u64, Vec<&'static str>)> {
    vec![
        (vec![6, 7, 7], 12, vec!["optimal-k3", "lp-general", "oblivious"]),
        (vec![3, 4, 5, 6, 7], 10, vec!["lp-general", "oblivious"]),
        (vec![4, 4, 5, 5, 6, 6, 7, 7], 8, vec!["lp-general", "oblivious", "combinatorial"]),
        (vec![4, 4, 4, 5, 5, 5, 6, 6, 6, 7, 7, 7], 12, vec!["combinatorial"]),
    ]
}

const CODERS: &[&str] = &["pairing", "greedy", "multicast", "memshare", "combinatorial"];

#[test]
fn plan_json_is_byte_identical_across_thread_counts() {
    let mut built = 0usize;
    for (storage, n, placers) in shapes() {
        let cl = cluster(&storage);
        let job = small_job(n);
        for placer in placers {
            // Every coder that serves the placement, plus the placer's
            // default and the uncoded baseline.
            let coder_choices: Vec<Option<&str>> =
                std::iter::once(None).chain(CODERS.iter().copied().map(Some)).collect();
            for coder in coder_choices {
                for mode in [ShuffleMode::Coded, ShuffleMode::Uncoded] {
                    if mode == ShuffleMode::Uncoded && coder.is_some() {
                        continue; // uncoded ignores the coder choice
                    }
                    let build = |threads: usize| {
                        let mut b = JobBuilder::new(&cl, &job)
                            .placer(placer)
                            .mode(mode)
                            .threads(threads);
                        if let Some(c) = coder {
                            b = b.coder(c);
                        }
                        b.build()
                    };
                    let reference = match build(1) {
                        Ok(p) => p.to_json_string(),
                        Err(_) => continue, // combo rejects this shape
                    };
                    for threads in [2usize, 8, 0] {
                        let plan = build(threads).unwrap_or_else(|e| {
                            panic!(
                                "K={} {placer} x {coder:?} {mode:?}: serial build \
                                 succeeded but threads={threads} failed: {e}",
                                cl.k()
                            )
                        });
                        assert_eq!(
                            reference,
                            plan.to_json_string(),
                            "K={} {placer} x {coder:?} {mode:?} threads={threads}: \
                             plan JSON diverged",
                            cl.k()
                        );
                    }
                    built += 1;
                }
            }
        }
    }
    assert!(built >= 20, "sweep too small: only {built} combos built");
}

#[test]
fn sharded_simplex_pricing_matches_unsharded_on_section_v_lps() {
    // The real §V LPs (not toy models): same basis walk — pivot count,
    // objective, and every variable value, bit for bit.
    for storage in [vec![6u64, 7, 7], vec![3, 5, 6, 8], vec![3, 4, 5, 6, 7]] {
        let p = ParamsK::new(storage.clone(), 12).unwrap();
        let model = build_lp::<f64>(&p, DEFAULT_COLLECTION_CAP);
        let serial = solve(&model.lp).unwrap();
        for threads in [2usize, 3, 8] {
            let sharded = solve_with_threads(&model.lp, threads).unwrap();
            assert_eq!(
                serial.objective.to_bits(),
                sharded.objective.to_bits(),
                "{storage:?} threads={threads}: objective"
            );
            assert_eq!(
                serial.pivots, sharded.pivots,
                "{storage:?} threads={threads}: pivot walk"
            );
            assert_eq!(
                serial.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                sharded.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{storage:?} threads={threads}: solution values"
            );
        }
    }
}

#[test]
fn lp_cap_builds_are_deterministic_too() {
    // The --lp-cap knob composes with threading: a truncating cap must
    // truncate identically (same dropped counts, same placement bytes)
    // at every thread count on the legacy capped route.
    let cl = cluster(&[3, 4, 5, 6]);
    let job = small_job(8);
    let reference = JobBuilder::new(&cl, &job)
        .placer("lp-capped")
        .lp_cap(1)
        .build()
        .unwrap();
    assert!(
        !reference.dropped_collections.is_empty(),
        "cap=1 must truncate at K=4"
    );
    for threads in [2usize, 8] {
        let plan = JobBuilder::new(&cl, &job)
            .placer("lp-capped")
            .lp_cap(1)
            .threads(threads)
            .build()
            .unwrap();
        assert_eq!(reference.to_json_string(), plan.to_json_string(), "threads={threads}");
        assert_eq!(reference.dropped_collections, plan.dropped_collections);
    }
}

#[test]
fn exact_lp_builds_are_byte_identical_across_thread_counts() {
    // The exact path adds threaded pricing inside the revised simplex
    // and a seeded grow-and-certify loop; none of it may move a byte of
    // the artifact — including the serialized `lp_solver` work counters.
    let cl = cluster(&[4, 4, 5, 5, 6, 6, 7, 7]);
    let job = small_job(8);
    let reference = JobBuilder::new(&cl, &job).placer("lp-general").build().unwrap();
    let stats = reference.lp_stats.expect("exact route records counters");
    assert!(stats.certified, "K=8 must certify: {stats:?}");
    assert!(reference.dropped_collections.is_empty());
    for threads in [2usize, 8, 0] {
        let plan = JobBuilder::new(&cl, &job)
            .placer("lp-general")
            .threads(threads)
            .build()
            .unwrap();
        assert_eq!(
            reference.to_json_string(),
            plan.to_json_string(),
            "threads={threads}: exact-LP plan JSON diverged"
        );
        assert_eq!(reference.lp_stats, plan.lp_stats, "threads={threads}");
    }
}
