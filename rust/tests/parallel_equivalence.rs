//! Three-way executor equivalence — serial / parallel / pipelined: for
//! every `Placer` × `ShuffleCoder` combination that builds a `Plan` at
//! K = 3..6 (plus the uncoded mode), multi-batch runs in all three
//! `ExecMode`s must be **bit-identical**, batch by batch — same
//! `RunReport` numbers, same `NetReport` (including the float clock and
//! the batch-epoch tag, bit for bit), and same decoded IV bytes at every
//! node after the final batch.
//!
//! This is the acceptance gate of the sharded and pipelined executors:
//! parallelism and batch pipelining may only change wall-clock, never a
//! single output bit. Batch counts are drawn deterministically from
//! 1..=8 per combination (see `prop::Gen`), so the sweep also exercises
//! the pipeline's fill/drain edges (1 batch = nothing to overlap).

use hetcdc::coding::builtin_coders;
use hetcdc::coding::plan::IvId;
use hetcdc::coding::decoder;
use hetcdc::engine::{ExecConfig, ExecMode, Executor, JobBuilder, NativeBackend, Plan, RunReport};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::net::{FaultSpec, Topology};
use hetcdc::placement::builtin_placers;
use hetcdc::prop::Gen;

fn cluster(storage: &[u64]) -> ClusterSpec {
    let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
    for (node, &m) in c.nodes.iter_mut().zip(storage) {
        node.storage = m;
    }
    // Heterogeneous uplinks and map rates so the clocks actually exercise
    // the per-node rate table.
    for (i, node) in c.nodes.iter_mut().enumerate() {
        node.uplink_mbps = 400.0 + 175.0 * (i % 3) as f64;
        node.map_files_per_s = 100.0 * (1 + i % 4) as f64;
    }
    c
}

fn small_job(n: u64) -> JobSpec {
    let mut job = JobSpec::terasort(n);
    job.t = 8;
    job.keys_per_file = 16;
    job
}

/// The fixed K = 3..6 shapes the equivalence sweep runs over.
fn shapes() -> Vec<(Vec<u64>, u64)> {
    vec![
        (vec![6, 7, 7], 12),
        (vec![3, 4, 5, 6], 8),
        (vec![3, 4, 5, 6, 7], 10),
        (vec![2, 3, 3, 4, 4, 5], 8),
    ]
}

fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.k, b.k, "{ctx}: k");
    assert_eq!(a.seed, b.seed, "{ctx}: seed");
    assert_eq!(a.payload_bytes, b.payload_bytes, "{ctx}: payload_bytes");
    assert_eq!(a.wire_bytes, b.wire_bytes, "{ctx}: wire_bytes");
    assert_eq!(a.messages, b.messages, "{ctx}: messages");
    assert_eq!(
        a.load_equations.to_bits(),
        b.load_equations.to_bits(),
        "{ctx}: load_equations"
    );
    assert_eq!(
        a.map_time_s.to_bits(),
        b.map_time_s.to_bits(),
        "{ctx}: map_time_s"
    );
    assert_eq!(
        a.shuffle_time_s.to_bits(),
        b.shuffle_time_s.to_bits(),
        "{ctx}: shuffle_time_s"
    );
    assert_eq!(
        a.job_time_s.to_bits(),
        b.job_time_s.to_bits(),
        "{ctx}: job_time_s"
    );
    assert_eq!(a.verified, b.verified, "{ctx}: verified");
    assert_eq!(
        a.max_abs_err.to_bits(),
        b.max_abs_err.to_bits(),
        "{ctx}: max_abs_err"
    );
}

/// Run `batches` batches of one plan in all three modes and diff
/// everything observable, batch by batch.
fn check_plan(plan: &Plan, threads: usize, batches: usize, ctx: &str) {
    let mut be = NativeBackend;
    let seeds: Vec<u64> = (0..batches as u64)
        .map(|b| plan.job.seed ^ 0xA5A5 ^ (b << 8))
        .collect();

    let mut serial = Executor::with_config(plan, ExecConfig::default()).unwrap();
    assert_eq!(serial.mode().as_str(), "serial");
    let rs = serial.run_batches(&mut be, &seeds).unwrap();

    let cfg = ExecConfig::default().threads(threads);
    let mut parallel = Executor::with_config(plan, cfg.mode(ExecMode::Parallel)).unwrap();
    assert_eq!(parallel.mode(), ExecMode::Parallel);
    assert_eq!(parallel.mode().as_str(), "parallel");
    let rp = parallel.run_batches(&mut be, &seeds).unwrap();

    let mut pipelined = Executor::with_config(plan, cfg.mode(ExecMode::Pipelined)).unwrap();
    assert_eq!(pipelined.mode().as_str(), "pipelined");
    let rq = pipelined.run_batches(&mut be, &seeds).unwrap();

    assert_eq!(rs.len(), batches, "{ctx}: serial batch count");
    assert_eq!(rp.len(), batches, "{ctx}: parallel batch count");
    assert_eq!(rq.len(), batches, "{ctx}: pipelined batch count");
    for b in 0..batches {
        assert!(rs[b].verified, "{ctx}: serial batch {b} failed verification");
        assert_reports_identical(&rs[b], &rp[b], &format!("{ctx} [parallel batch {b}]"));
        assert_reports_identical(&rs[b], &rq[b], &format!("{ctx} [pipelined batch {b}]"));
    }
    for (exec, mode) in [(&serial, "serial"), (&parallel, "parallel"), (&pipelined, "pipelined")] {
        assert_eq!(exec.batches_run(), batches as u64, "{ctx}: {mode} batches_run");
        // One metering epoch per batch, in every mode.
        assert_eq!(exec.net_report().epoch, batches as u64, "{ctx}: {mode} ledger epoch");
    }
    assert_eq!(
        serial.net_report(),
        parallel.net_report(),
        "{ctx}: parallel NetReport (bit-exact, including the clock)"
    );
    assert_eq!(
        serial.net_report(),
        pipelined.net_report(),
        "{ctx}: pipelined NetReport (bit-exact, including the clock)"
    );
    // Per-round ledger sections mirror the plan's IR in every mode (the
    // NetReport equality above already proves the three modes agree).
    let nr = serial.net_report();
    assert_eq!(
        nr.rounds.len(),
        plan.shuffle.round_count(),
        "{ctx}: round sections"
    );
    // Recovery unicasts (if a fault spec forced any) account into the
    // round sections too, one message per NACK round trip.
    assert_eq!(
        nr.rounds.iter().map(|s| s.msgs).sum::<u64>(),
        plan.shuffle.n_broadcasts() as u64 + nr.nack_rtts,
        "{ctx}: round messages"
    );

    // Complete post-shuffle state of the final batch: every (node,
    // group, subfile) IV slot agrees — both the bytes and the
    // known/unknown status — across all three modes.
    let k = plan.cluster.k();
    let n_sub = plan.alloc.n_sub();
    for node in 0..k {
        for group in 0..k {
            for sub in 0..n_sub {
                let iv = IvId { group, sub };
                assert_eq!(
                    serial.iv(node, iv),
                    parallel.iv(node, iv),
                    "{ctx}: parallel node {node} {iv:?}"
                );
                assert_eq!(
                    serial.iv(node, iv),
                    pipelined.iv(node, iv),
                    "{ctx}: pipelined node {node} {iv:?}"
                );
            }
        }
    }
}

#[test]
fn every_placer_coder_combo_is_mode_equivalent_k3_to_6() {
    // Deterministic per-combination batch counts over the full 1..=8
    // range: the property sweep covers single-batch (no overlap), the
    // two-batch minimum pipeline, and longer steady-state runs.
    let mut batch_gen = Gen::new(0xB47C_11FE);
    for (storage, n) in shapes() {
        let cl = cluster(&storage);
        let job = small_job(n);
        for placer in builtin_placers() {
            let alloc = match placer.place(&cl, &job) {
                Ok(a) => a,
                Err(_) => continue, // shape not served (e.g. K=3-only)
            };
            for coder in builtin_coders() {
                let plan = match JobBuilder::new(&cl, &job)
                    .custom_allocation(alloc.clone())
                    .coder(coder.name())
                    .mode(ShuffleMode::Coded)
                    .build()
                {
                    Ok(p) => p,
                    Err(_) => continue, // combo rejects this shape
                };
                let batches = batch_gen.usize_in(1..=8);
                let ctx = format!(
                    "K={} storage={storage:?} {} x {} batches={batches}",
                    cl.k(),
                    placer.name(),
                    coder.name()
                );
                check_plan(&plan, 3, batches, &ctx);
            }
            // The uncoded baseline must be mode-equivalent too.
            let plan = JobBuilder::new(&cl, &job)
                .custom_allocation(alloc.clone())
                .mode(ShuffleMode::Uncoded)
                .build()
                .unwrap();
            let batches = batch_gen.usize_in(1..=8);
            let ctx = format!(
                "K={} storage={storage:?} {} x uncoded batches={batches}",
                cl.k(),
                placer.name()
            );
            check_plan(&plan, 3, batches, &ctx);
        }
    }
}

#[test]
fn every_placer_coder_combo_is_mode_equivalent_on_a_rack_topology() {
    // The concurrent-round scheduler must be as mode-oblivious as the
    // shared medium: under a 2-rack oversubscribed fabric, every
    // placer × coder combination (plus uncoded) stays bit-identical
    // across serial/parallel/pipelined — same `NetReport` including the
    // per-link ledgers and per-round makespans, which only exist on
    // switched topologies. `check_plan` compares full `NetReport`s with
    // `==`, so `links` and `makespan_s`/`critical_group` are in the diff.
    let mut batch_gen = Gen::new(0x7AC4_0217);
    let rack = Topology::Rack { racks: 2, oversub: 3.0 };
    for (storage, n) in shapes() {
        let cl = cluster(&storage).with_topology(rack);
        let job = small_job(n);
        for placer in builtin_placers() {
            let alloc = match placer.place(&cl, &job) {
                Ok(a) => a,
                Err(_) => continue,
            };
            for coder in builtin_coders() {
                let plan = match JobBuilder::new(&cl, &job)
                    .custom_allocation(alloc.clone())
                    .coder(coder.name())
                    .mode(ShuffleMode::Coded)
                    .build()
                {
                    Ok(p) => p,
                    Err(_) => continue,
                };
                let batches = batch_gen.usize_in(1..=3);
                let ctx = format!(
                    "rack K={} storage={storage:?} {} x {} batches={batches}",
                    cl.k(),
                    placer.name(),
                    coder.name()
                );
                check_plan(&plan, 3, batches, &ctx);
                // The switched path was actually exercised: the report
                // carries a ledger per access link plus the rack trunks.
                let nr = Executor::with_config(&plan, ExecConfig::default())
                    .and_then(|mut e| {
                        e.run_batch(&mut NativeBackend, job.seed).map(|_| e.net_report())
                    })
                    .unwrap();
                assert_eq!(nr.links.len(), cl.k() + 2, "{ctx}: link ledgers");
                for round in &nr.rounds {
                    assert!(
                        round.makespan_s <= round.elapsed_s + 1e-12,
                        "{ctx}: round makespan {} above serialized bound {}",
                        round.makespan_s,
                        round.elapsed_s
                    );
                }
            }
            let plan = JobBuilder::new(&cl, &job)
                .custom_allocation(alloc.clone())
                .mode(ShuffleMode::Uncoded)
                .build()
                .unwrap();
            let batches = batch_gen.usize_in(1..=3);
            let ctx = format!(
                "rack K={} storage={storage:?} {} x uncoded batches={batches}",
                cl.k(),
                placer.name()
            );
            check_plan(&plan, 3, batches, &ctx);
        }
    }
}

#[test]
fn combinatorial_grid_is_mode_equivalent_k4_to_k12() {
    // Grid-feasible shapes (storage floors chosen so the combinatorial
    // placer factors K = q·r): K=4 (q=2, r=2), K=6 (q=2, r=3),
    // K=8 (q=2, r=4), K=12 (q=3, r=4) — the larger-K regimes the main
    // sweep's storage-tight shapes cannot reach. Every coder that serves
    // a grid allocation must stay bit-identical across all three modes.
    let grid_shapes: Vec<(Vec<u64>, u64, usize)> = vec![
        (vec![4, 4, 5, 6], 8, 3),
        (vec![4, 4, 4, 5, 5, 5], 8, 3),
        (vec![4, 4, 5, 5, 6, 6, 7, 7], 8, 3),
        (vec![4, 4, 4, 5, 5, 5, 6, 6, 6, 7, 7, 7], 12, 2),
    ];
    let mut batch_gen = Gen::new(0x6B1D_C0DE);
    for (storage, n, max_batches) in grid_shapes {
        let cl = cluster(&storage);
        let job = small_job(n);
        for coder in ["combinatorial", "greedy", "pairing"] {
            let plan = JobBuilder::new(&cl, &job)
                .placer("combinatorial")
                .coder(coder)
                .mode(ShuffleMode::Coded)
                .build()
                .unwrap_or_else(|e| {
                    panic!("K={} combinatorial x {coder}: {e}", cl.k())
                });
            let batches = batch_gen.usize_in(1..=max_batches);
            let ctx = format!(
                "K={} grid combinatorial x {coder} batches={batches}",
                cl.k()
            );
            check_plan(&plan, 3, batches, &ctx);
        }
        // The uncoded baseline on the grid placement, too.
        let plan = JobBuilder::new(&cl, &job)
            .placer("combinatorial")
            .mode(ShuffleMode::Uncoded)
            .build()
            .unwrap();
        let batches = batch_gen.usize_in(1..=max_batches);
        check_plan(
            &plan,
            3,
            batches,
            &format!("K={} grid x uncoded batches={batches}", cl.k()),
        );
    }
}

#[test]
fn every_placer_coder_combo_is_mode_equivalent_under_stragglers() {
    // The fault-injection layer must be as mode-oblivious as the fabric:
    // with a fixed-seed straggler spec baked into the cluster, every
    // placer × coder combination at K = 3..6 stays bit-identical across
    // serial/parallel/pipelined — same `NetReport` including the
    // straggler-shifted clock and `straggler_delay_s`, batch by batch.
    // The amp is large so the delay is guaranteed nonzero: the sweep
    // proves the straggled path itself (not a degenerate zero-jitter
    // case) is deterministic.
    let straggle = FaultSpec::parse("straggle:seed=0x5EED,amp=50").unwrap();
    let mut batch_gen = Gen::new(0xFA17_0BAD);
    for (storage, n) in shapes() {
        let cl = cluster(&storage).with_faults(straggle.clone());
        let job = small_job(n);
        for placer in builtin_placers() {
            let alloc = match placer.place(&cl, &job) {
                Ok(a) => a,
                Err(_) => continue,
            };
            for coder in builtin_coders() {
                let plan = match JobBuilder::new(&cl, &job)
                    .custom_allocation(alloc.clone())
                    .coder(coder.name())
                    .mode(ShuffleMode::Coded)
                    .build()
                {
                    Ok(p) => p,
                    Err(_) => continue,
                };
                let batches = batch_gen.usize_in(1..=4);
                let ctx = format!(
                    "straggle K={} storage={storage:?} {} x {} batches={batches}",
                    cl.k(),
                    placer.name(),
                    coder.name()
                );
                check_plan(&plan, 3, batches, &ctx);
                // The jitter actually bit: the ledger records a positive
                // aggregate wait, and it is identical batch over batch
                // (the spec belongs to the cluster, not the batch).
                let mut exec = Executor::with_config(&plan, ExecConfig::default()).unwrap();
                exec.run_batch(&mut NativeBackend, job.seed).unwrap();
                let first = exec.net_report().straggler_delay_s;
                assert!(first > 0.0, "{ctx}: straggler_delay_s = {first}");
                exec.run_batch(&mut NativeBackend, job.seed ^ 1).unwrap();
                assert_eq!(
                    exec.net_report().straggler_delay_s.to_bits(),
                    first.to_bits(),
                    "{ctx}: jitter must survive the per-batch net reset"
                );
            }
        }
    }
}

#[test]
fn repair_f1_plans_survive_every_single_broadcast_loss() {
    // Degraded-decode property: a plan built under `repair:f=1` carries
    // enough redundancy that pruning ANY one broadcast — original or
    // repair copy — still lets the symbolic decoder recover every IV at
    // every node. (The builder already checks this at assembly time; the
    // test proves the shipped plan artifact, not just the build gate.)
    let repair = FaultSpec::parse("repair:f=1").unwrap();
    for (storage, n) in shapes() {
        let cl = cluster(&storage).with_faults(repair.clone());
        let job = small_job(n);
        for placer in builtin_placers() {
            let alloc = match placer.place(&cl, &job) {
                Ok(a) => a,
                Err(_) => continue,
            };
            for coder in builtin_coders() {
                let plan = match JobBuilder::new(&cl, &job)
                    .custom_allocation(alloc.clone())
                    .coder(coder.name())
                    .mode(ShuffleMode::Coded)
                    .build()
                {
                    Ok(p) => p,
                    Err(_) => continue,
                };
                let ctx = format!(
                    "repair:f=1 K={} storage={storage:?} {} x {}",
                    cl.k(),
                    placer.name(),
                    coder.name()
                );
                let total = plan.shuffle.n_broadcasts();
                assert!(total > 0, "{ctx}: empty shuffle");
                for lost in 0..total {
                    let pruned = plan.shuffle.without_broadcast(lost);
                    let report = decoder::verify(&plan.alloc, &pruned);
                    assert!(
                        report.is_complete(),
                        "{ctx}: losing broadcast {lost}/{total} left IVs unrecovered"
                    );
                }
                // And the sweep-level guarantee directly:
                decoder::verify_loss_patterns(&plan.alloc, &plan.shuffle, 1)
                    .unwrap_or_else(|e| panic!("{ctx}: {e}"));
                // Repair plans execute and verify end-to-end too, in all
                // three modes.
                check_plan(&plan, 3, 2, &ctx);
            }
        }
    }
}

#[test]
fn repair_f1_recovers_every_single_runtime_erasure() {
    // Runtime counterpart of `repair_f1_plans_survive_every_single_broadcast_loss`:
    // not just the symbolic decoder, but the *executor* must absorb any
    // one erased broadcast on an f=1 plan — decoded IVs bit-equal to the
    // fault-free run, no retransmission needed — in all three exec modes
    // at K = 3..6.
    let repair = FaultSpec::parse("repair:f=1").unwrap();
    for (storage, n) in shapes() {
        let cl = cluster(&storage).with_faults(repair.clone());
        let job = small_job(n);
        let plan = JobBuilder::new(&cl, &job).build().unwrap();
        let k = cl.k();
        let n_sub = plan.alloc.n_sub();
        let mut be = NativeBackend;
        let mut reference = Executor::with_config(&plan, ExecConfig::default()).unwrap();
        let clean = reference.run_batch(&mut be, job.seed).unwrap();
        assert!(clean.verified);
        let clean_net = reference.net_report();
        for (r, g, b) in plan.shuffle.coords() {
            let faults =
                FaultSpec::parse(&format!("repair:f=1;erase:list={r}.{g}.{b}")).unwrap();
            for (mode, threads) in [
                (ExecMode::Serial, 0usize),
                (ExecMode::Parallel, 3),
                (ExecMode::Pipelined, 2),
            ] {
                let ctx = format!("K={k} erase={r}.{g}.{b} mode={}", mode.as_str());
                let cfg = ExecConfig {
                    mode,
                    threads,
                    faults: Some(faults.clone()),
                };
                let mut exec = Executor::with_config(&plan, cfg).unwrap();
                let rr = exec.run_batch(&mut be, job.seed).unwrap();
                assert!(rr.verified, "{ctx}: verification");
                // Plan traffic is exactly the fault-free run's.
                assert_eq!(rr.payload_bytes, clean.payload_bytes, "{ctx}: payload");
                assert_eq!(rr.wire_bytes, clean.wire_bytes, "{ctx}: wire");
                assert_eq!(rr.messages, clean.messages, "{ctx}: messages");
                let nr = exec.net_report();
                assert_eq!(nr.erased_broadcasts, 1, "{ctx}: erased count");
                assert_eq!(
                    nr.retransmit_rounds, 0,
                    "{ctx}: f=1 must absorb a single erasure without resends"
                );
                assert_eq!(nr.recovery_bytes, 0, "{ctx}: recovery bytes");
                assert_eq!(nr.total_bytes, clean_net.total_bytes, "{ctx}: totals");
                // Decoded IVs bit-equal to fault-free, at every slot.
                for node in 0..k {
                    for group in 0..k {
                        for sub in 0..n_sub {
                            let iv = IvId { group, sub };
                            assert_eq!(
                                reference.iv(node, iv),
                                exec.iv(node, iv),
                                "{ctx}: node {node} {iv:?}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn seeded_erasure_sweep_is_mode_equivalent() {
    // The erasure layer must be as mode-oblivious as the fabric: with a
    // seeded erasure spec baked into the cluster — both on a bare plan
    // (stranded IVs force retransmission recovery) and on an f=1 repaired
    // plan — multi-batch runs stay bit-identical across
    // serial/parallel/pipelined: same `RunReport`s, same `NetReport`
    // including the four recovery counters, same decoded IV bytes.
    for (storage, n) in shapes() {
        for spec in ["erase:seed=0x5eed,p=0.25", "repair:f=1;erase:seed=0x5eed,p=0.25"] {
            let faults = FaultSpec::parse(spec).unwrap();
            let cl = cluster(&storage).with_faults(faults);
            let job = small_job(n);
            let plan = JobBuilder::new(&cl, &job).build().unwrap();
            check_plan(&plan, 3, 3, &format!("K={} {spec}", cl.k()));
            // The erased path was actually exercised at p=0.25 over 3
            // batches — the keyed hash must hit at least once.
            let mut exec = Executor::with_config(&plan, ExecConfig::default()).unwrap();
            let mut erased_total = 0;
            for batch in 0..3u64 {
                let r = exec.run_batch(&mut NativeBackend, job.seed + batch).unwrap();
                assert!(r.verified, "K={} {spec} batch {batch}", cl.k());
                erased_total += exec.net_report().erased_broadcasts;
            }
            assert!(
                erased_total > 0,
                "K={} {spec}: no broadcast erased across 3 batches",
                cl.k()
            );
        }
    }
}

#[test]
fn equivalence_holds_for_every_thread_count() {
    let cl = cluster(&[4, 8, 12]);
    let job = small_job(12);
    let plan = JobBuilder::new(&cl, &job).placer("optimal-k3").build().unwrap();
    for threads in [0usize, 1, 2, 3, 7, 64] {
        check_plan(&plan, threads, 3, &format!("threads={threads}"));
    }
}

#[test]
fn parallel_batches_still_match_plan_predictions() {
    // The staged-pipeline contract survives sharding: measured ==
    // predicted for every batch, in parallel mode.
    let cl = cluster(&[3, 4, 5, 6, 7]);
    let job = small_job(10);
    let plan = JobBuilder::new(&cl, &job).build().unwrap();
    let mut be = NativeBackend;
    let mut exec =
        Executor::with_config(&plan, ExecConfig::default().mode(ExecMode::Parallel)).unwrap();
    for batch in 0..3u64 {
        let r = exec.run_batch(&mut be, job.seed + batch).unwrap();
        assert!(r.verified);
        assert_eq!(r.payload_bytes, plan.predicted.payload_bytes);
        assert_eq!(r.wire_bytes, plan.predicted.wire_bytes);
        assert_eq!(r.messages, plan.predicted.messages);
        assert_eq!(
            r.shuffle_time_s.to_bits(),
            plan.predicted.shuffle_time_s.to_bits()
        );
    }
    assert_eq!(exec.batches_run(), 3);
}

#[test]
fn pipelined_batches_still_match_plan_predictions() {
    // ... and survives batch pipelining: every overlapped batch still
    // reproduces the plan's predictions exactly.
    let cl = cluster(&[3, 4, 5, 6, 7]);
    let job = small_job(10);
    let plan = JobBuilder::new(&cl, &job).build().unwrap();
    let mut be = NativeBackend;
    let mut exec =
        Executor::with_config(&plan, ExecConfig::default().mode(ExecMode::Pipelined)).unwrap();
    let seeds: Vec<u64> = (0..4u64).map(|b| job.seed + b).collect();
    let reports = exec.run_batches(&mut be, &seeds).unwrap();
    assert_eq!(reports.len(), 4);
    for r in &reports {
        assert!(r.verified);
        assert_eq!(r.payload_bytes, plan.predicted.payload_bytes);
        assert_eq!(r.wire_bytes, plan.predicted.wire_bytes);
        assert_eq!(r.messages, plan.predicted.messages);
        assert_eq!(
            r.shuffle_time_s.to_bits(),
            plan.predicted.shuffle_time_s.to_bits()
        );
        assert_eq!(r.map_time_s.to_bits(), plan.predicted.map_time_s.to_bits());
    }
    assert_eq!(exec.batches_run(), 4);
    assert_eq!(exec.net_report().epoch, 4);
}
