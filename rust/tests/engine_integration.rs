//! Cross-module integration: placement strategies x shuffle modes x
//! workloads through the full engine, against theory and each other.

use hetcdc::engine::{Engine, NativeBackend};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode, WorkloadKind};
use hetcdc::prop;
use hetcdc::theory::load;
use hetcdc::theory::params::Params3;

fn cluster(storage: &[u64]) -> ClusterSpec {
    let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
    for (node, &m) in c.nodes.iter_mut().zip(storage) {
        node.storage = m;
    }
    c
}

fn small_job(kind: WorkloadKind, n: u64) -> JobSpec {
    let mut j = match kind {
        WorkloadKind::WordCount => JobSpec::wordcount(n),
        WorkloadKind::TeraSort => JobSpec::terasort(n),
    };
    j.t = 8;
    j.vocab = 32;
    j.keys_per_file = 32;
    j
}

#[test]
fn every_strategy_mode_workload_combination_verifies() {
    let c3 = cluster(&[6, 7, 7]);
    let c3h = cluster(&[8, 8, 8]);
    let cases: Vec<(&ClusterSpec, &str)> = vec![
        (&c3, "optimal-k3"),
        (&c3, "lp-general"),
        (&c3, "oblivious"),
        (&c3h, "homogeneous"),
    ];
    for (cl, placer) in cases {
        for kind in [WorkloadKind::WordCount, WorkloadKind::TeraSort] {
            for mode in [ShuffleMode::Coded, ShuffleMode::Uncoded] {
                let job = small_job(kind, 12);
                let mut be = NativeBackend;
                let r = Engine::new(cl, &job, &mut be)
                    .run(placer, mode)
                    .unwrap_or_else(|e| panic!("{placer} {kind:?} {mode:?}: {e}"));
                assert!(
                    r.verified,
                    "{placer} {kind:?} {mode:?}: max_abs_err {}",
                    r.max_abs_err
                );
            }
        }
    }
}

#[test]
fn strategy_ordering_holds_on_heterogeneous_cluster() {
    // aware-coded <= aware-uncoded <= oblivious-uncoded; and
    // aware-coded <= oblivious-coded (heterogeneity awareness helps).
    let cl = cluster(&[4, 8, 12]);
    let job = small_job(WorkloadKind::TeraSort, 12);
    let mut be = NativeBackend;
    let mut run = |s: &str, m: ShuffleMode| {
        Engine::new(&cl, &job, &mut be).run(s, m).unwrap().load_equations
    };
    let aware_coded = run("optimal-k3", ShuffleMode::Coded);
    let aware_uncoded = run("optimal-k3", ShuffleMode::Uncoded);
    let obliv_coded = run("oblivious", ShuffleMode::Coded);
    assert!(aware_coded <= aware_uncoded);
    assert!(aware_coded <= obliv_coded);
    let p = Params3::new(4, 8, 12, 12).unwrap();
    assert_eq!(aware_coded, load::lstar(&p));
    assert_eq!(aware_uncoded, load::uncoded(&p));
}

#[test]
fn lp_and_optimal_k3_agree_on_measured_load() {
    // Both placements achieve L* for K=3 (Remark 5, end-to-end version).
    prop::run("LP == optimal-k3 measured", 10, |g| {
        let n = g.u64_in(2..=8);
        let m1 = g.u64_in(1..=n);
        let m2 = g.u64_in(1..=n);
        let m3 = g.u64_in(1..=n);
        let Ok(p) = Params3::new(m1, m2, m3, n) else {
            return Ok(());
        };
        let cl = cluster(&[m1, m2, m3]);
        let job = small_job(WorkloadKind::TeraSort, n);
        let mut be = NativeBackend;
        let opt = Engine::new(&cl, &job, &mut be)
            .run("optimal-k3", ShuffleMode::Coded)
            .map_err(|e| format!("{p}: {e}"))?;
        let lp = Engine::new(&cl, &job, &mut be)
            .run("lp-general", ShuffleMode::Coded)
            .map_err(|e| format!("{p}: {e}"))?;
        // LP-realized placements round to integers; the measured load may
        // exceed L* by the rounding slack but must stay below uncoded.
        prop::check(
            opt.load_equations == load::lstar(&p)
                && lp.load_equations + 1e-9 >= opt.load_equations
                && lp.load_equations <= load::uncoded(&p) + 1e-9,
            format!(
                "{p}: opt {} lp {} L* {} uncoded {}",
                opt.load_equations,
                lp.load_equations,
                load::lstar(&p),
                load::uncoded(&p)
            ),
        )
    });
}

#[test]
fn wire_overhead_accounting_is_consistent() {
    let cl = cluster(&[6, 7, 7]);
    let job = small_job(WorkloadKind::TeraSort, 12);
    let mut be = NativeBackend;
    let r = Engine::new(&cl, &job, &mut be)
        .run("optimal-k3", ShuffleMode::Coded)
        .unwrap();
    assert!(r.wire_bytes > r.payload_bytes);
    // payload = load_units * iv_bytes (whole-IV plan).
    assert_eq!(
        r.payload_bytes,
        (r.load_equations * r.sp as f64) as u64 * job.iv_bytes() as u64
    );
    // Headers: 16 + 12 per part.
    let min_headers = r.messages * (16 + 12);
    assert!(r.wire_bytes >= r.payload_bytes + min_headers);
}

#[test]
fn report_json_roundtrips() {
    let cl = cluster(&[6, 7, 7]);
    let job = small_job(WorkloadKind::WordCount, 12);
    let mut be = NativeBackend;
    let r = Engine::new(&cl, &job, &mut be)
        .run("optimal-k3", ShuffleMode::Coded)
        .unwrap();
    let j = r.to_json();
    let parsed = hetcdc::util::json::Json::parse(&j.to_string()).unwrap();
    assert_eq!(parsed.get("load_equations").and_then(|v| v.as_f64()), Some(r.load_equations));
    assert_eq!(parsed.get("placement").and_then(|v| v.as_str()), Some("optimal-k3"));
}

#[test]
fn larger_n_scales_losslessly() {
    // N = 120 (240 subfiles): measured still equals theory exactly.
    let cl = cluster(&[60, 70, 70]);
    let mut job = JobSpec::terasort(120);
    job.t = 8;
    job.keys_per_file = 16;
    let p = Params3::new(60, 70, 70, 120).unwrap();
    let mut be = NativeBackend;
    let r = Engine::new(&cl, &job, &mut be)
        .run("optimal-k3", ShuffleMode::Coded)
        .unwrap();
    assert!(r.verified);
    assert_eq!(r.load_equations, load::lstar(&p)); // 120
}

#[test]
fn plan_roundtrips_through_json_and_executes() {
    // plan -> serialize -> deserialize (re-validated) -> execute: the
    // `hetcdc plan` / `hetcdc run --plan` contract, in-process.
    use hetcdc::engine::{ExecConfig, Executor, JobBuilder, Plan};
    let cl = cluster(&[6, 7, 7]);
    let job = small_job(WorkloadKind::TeraSort, 12);
    let plan = JobBuilder::new(&cl, &job)
        .placer("optimal-k3")
        .mode(ShuffleMode::Coded)
        .build()
        .unwrap();
    let restored = Plan::from_json_str(&plan.to_json_string()).unwrap();
    let mut be = NativeBackend;
    let mut exec = Executor::with_config(&restored, ExecConfig::default()).unwrap();
    let r1 = exec.run_batch(&mut be, 1).unwrap();
    let r2 = exec.run_batch(&mut be, 2).unwrap();
    assert!(r1.verified && r2.verified);
    assert_eq!(r1.load_equations, 12.0);
    assert_eq!(r1.load_equations, r2.load_equations);
    assert_eq!(r1.payload_bytes, r2.payload_bytes);
    assert_eq!(r1.shuffle_time_s, r2.shuffle_time_s);
}

#[test]
fn plan_cache_serves_repeated_shapes() {
    use hetcdc::engine::{ExecConfig, Executor, PlanCache};
    let cl = cluster(&[6, 7, 7]);
    let mut cache = PlanCache::new(8);
    let mut be = NativeBackend;
    for batch in 0..4u64 {
        let mut job = small_job(WorkloadKind::TeraSort, 12);
        job.seed = batch; // seed churn must not force rebuilds
        let plan = cache
            .get_or_build(&cl, &job, "auto", None, ShuffleMode::Coded)
            .unwrap();
        let r = Executor::with_config(&plan, ExecConfig::default())
            .unwrap()
            .run_batch(&mut be, batch)
            .unwrap();
        assert!(r.verified);
        assert_eq!(r.load_equations, 12.0);
    }
    assert_eq!((cache.hits, cache.misses), (3, 1));
}
