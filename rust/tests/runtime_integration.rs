//! Live PJRT integration: load the AOT artifacts, execute them, and check
//! every kernel against the Rust-native oracles; then run the full engine
//! on the XLA backend. Requires `make artifacts` (skips with a clear
//! message otherwise).

use hetcdc::engine::{Engine, NativeBackend, XlaBackend};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::runtime::Runtime;
use hetcdc::util::rng::Xoshiro256;
use hetcdc::workloads;

fn runtime() -> Runtime {
    let dir = Runtime::default_dir();
    match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => panic!(
            "artifacts not available at {} — run `make artifacts` first: {e}",
            dir.display()
        ),
    }
}

#[test]
fn xor_artifact_matches_native_xor() {
    let mut rt = runtime();
    let mut rng = Xoshiro256::seed_from_u64(11);
    let (rows, cols) = (8usize, 128usize);
    let a: Vec<i32> = (0..rows * cols).map(|_| rng.next_u64() as i32).collect();
    let b: Vec<i32> = (0..rows * cols).map(|_| rng.next_u64() as i32).collect();
    let la = Runtime::lit_i32(&a, &[rows, cols]).unwrap();
    let lb = Runtime::lit_i32(&b, &[rows, cols]).unwrap();
    let got = rt.execute_to_i32("xor_blocks", &[la, lb]).unwrap();
    // Native path XORs the raw bytes.
    let a_bytes: Vec<u8> = a.iter().flat_map(|x| x.to_le_bytes()).collect();
    let b_bytes: Vec<u8> = b.iter().flat_map(|x| x.to_le_bytes()).collect();
    let want_bytes = hetcdc::coding::xor::xor_of(&a_bytes, &b_bytes);
    let want: Vec<i32> = want_bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    assert_eq!(got, want, "XLA xor kernel disagrees with Rust hot path");
}

#[test]
fn map_histogram_artifact_matches_native_exactly() {
    let mut rt = runtime();
    let m = rt.manifest.clone();
    let mut job = JobSpec::terasort(4);
    job.t = m.t;
    job.keys_per_file = m.keys_per_file;
    let q = m.q;
    let subs: Vec<usize> = (0..m.map_batch).collect();
    let native: Vec<Vec<Vec<u8>>> = subs
        .iter()
        .map(|&s| workloads::native_map(&job, q, s))
        .collect();
    let mut be = XlaBackend::new(&mut rt);
    use hetcdc::engine::MapBackend;
    let xla = be.map_subfiles(&job, q, &subs).unwrap();
    assert_eq!(native, xla, "i32 histogram must be bit-exact");
}

#[test]
fn map_project_artifact_matches_native_within_float_tolerance() {
    let mut rt = runtime();
    let m = rt.manifest.clone();
    let mut job = JobSpec::wordcount(4);
    job.t = m.t;
    job.vocab = m.vocab;
    let q = m.q;
    let subs: Vec<usize> = (0..5).collect(); // exercises padding (5 < 16)
    let native: Vec<Vec<Vec<u8>>> = subs
        .iter()
        .map(|&s| workloads::native_map(&job, q, s))
        .collect();
    let mut be = XlaBackend::new(&mut rt);
    use hetcdc::engine::MapBackend;
    let xla = be.map_subfiles(&job, q, &subs).unwrap();
    for (sub, (n, x)) in native.iter().zip(&xla).enumerate() {
        for g in 0..q {
            let nf = workloads::decode_payload(&job, &n[g]);
            let xf = workloads::decode_payload(&job, &x[g]);
            for (i, (a, b)) in nf.iter().zip(&xf).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-3 + 1e-4 * b.abs(),
                    "sub {sub} group {g} elem {i}: native {a} vs xla {b}"
                );
            }
        }
    }
}

#[test]
fn reduce_sum_artifact_matches_native() {
    let mut rt = runtime();
    let m = rt.manifest.clone();
    let mut job = JobSpec::wordcount(4);
    job.t = m.t;
    job.vocab = m.vocab;
    let q = m.q;
    let subs: Vec<usize> = (0..20).collect(); // > reduce_batch: chains partials
    let maps: Vec<Vec<Vec<u8>>> = subs
        .iter()
        .map(|&s| workloads::native_map(&job, q, s))
        .collect();
    let payloads: Vec<&[u8]> = maps.iter().map(|ivs| ivs[1].as_slice()).collect();
    let mut nat = NativeBackend;
    use hetcdc::engine::MapBackend;
    let want = nat.reduce_group(&job, &payloads).unwrap();
    let mut be = XlaBackend::new(&mut rt);
    let got = be.reduce_group(&job, &payloads).unwrap();
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-2 + 1e-4 * b.abs(),
            "elem {i}: xla {a} vs native {b}"
        );
    }
}

#[test]
fn engine_end_to_end_on_xla_backend_terasort() {
    let mut rt = runtime();
    let m = rt.manifest.clone();
    let mut cluster = ClusterSpec::ec2_like_3node(12);
    cluster.nodes[0].storage = 6;
    cluster.nodes[1].storage = 7;
    cluster.nodes[2].storage = 7;
    let mut job = JobSpec::terasort(12);
    job.t = m.t;
    job.keys_per_file = m.keys_per_file;
    let mut be = XlaBackend::new(&mut rt);
    let mut engine = Engine::new(&cluster, &job, &mut be);
    let coded = engine.run("optimal-k3", ShuffleMode::Coded).unwrap();
    assert!(coded.verified, "XLA coded run failed oracle check");
    assert_eq!(coded.load_equations, 12.0); // the paper's headline number
    assert_eq!(coded.max_abs_err, 0.0); // integer pipeline stays exact
    let uncoded = engine.run("optimal-k3", ShuffleMode::Uncoded).unwrap();
    assert!(uncoded.verified);
    assert_eq!(uncoded.load_equations, 16.0);
}

#[test]
fn engine_end_to_end_on_xla_backend_wordcount() {
    let mut rt = runtime();
    let m = rt.manifest.clone();
    let cluster = ClusterSpec::ec2_like_3node(12);
    let mut job = JobSpec::wordcount(12);
    job.t = m.t;
    job.vocab = m.vocab;
    let mut be = XlaBackend::new(&mut rt);
    let mut engine = Engine::new(&cluster, &job, &mut be);
    let r = engine.run("optimal-k3", ShuffleMode::Coded).unwrap();
    assert!(r.verified, "max_abs_err {}", r.max_abs_err);
    assert_eq!(r.load_equations, 12.0);
    assert_eq!(r.backend, "xla");
}

#[test]
fn job_mismatch_is_rejected_with_guidance() {
    let mut rt = runtime();
    let mut job = JobSpec::wordcount(12);
    job.t = 7; // does not match artifacts
    let mut be = XlaBackend::new(&mut rt);
    use hetcdc::engine::MapBackend;
    let err = be.map_subfiles(&job, 3, &[0]).unwrap_err();
    assert!(
        err.to_string().contains("make artifacts"),
        "unhelpful error: {err}"
    );
}

#[test]
fn xor_reduce_artifact_matches_native_fold() {
    let mut rt = runtime();
    let mut rng = Xoshiro256::seed_from_u64(23);
    let (layers, rows, cols) = (3usize, 8usize, 128usize);
    let stack: Vec<i32> = (0..layers * rows * cols)
        .map(|_| rng.next_u64() as i32)
        .collect();
    let lit = Runtime::lit_i32(&stack, &[layers, rows, cols]).unwrap();
    let got = rt.execute_to_i32("xor_reduce", &[lit]).unwrap();
    // Native fold of the layer byte-planes (the [2] multicast encoder path).
    let plane = rows * cols * 4;
    let bytes: Vec<u8> = stack.iter().flat_map(|x| x.to_le_bytes()).collect();
    let mut acc = bytes[..plane].to_vec();
    for l in 1..layers {
        hetcdc::coding::xor::xor_into(&mut acc, &bytes[l * plane..(l + 1) * plane]);
    }
    let want: Vec<i32> = acc
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    assert_eq!(got, want, "XLA xor_reduce disagrees with Rust multicast fold");
}
