//! Topology invariants: a switched fabric reschedules the shuffle — it
//! never changes what is sent. For random heterogeneous shapes and every
//! `Placer` × `ShuffleCoder` combination that builds, the rack-topology
//! run must move exactly the bytes/messages/rounds of the shared-medium
//! run, and each round's concurrent makespan must stay within its own
//! serialized fold. `Topology::Shared` itself is pinned bit-for-bit by a
//! committed v2 plan fixture: the simulated clock must reproduce the
//! documented `latency + bits/rate` fold exactly, so pre-topology
//! artifacts and reports survive this PR byte-identical.

use hetcdc::coding::builtin_coders;
use hetcdc::engine::{ExecConfig, Executor, JobBuilder, NativeBackend, Plan};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::net::{NetReport, Topology};
use hetcdc::placement::builtin_placers;
use hetcdc::prop;

fn cluster(storage: &[u64]) -> ClusterSpec {
    let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
    for (node, &m) in c.nodes.iter_mut().zip(storage) {
        node.storage = m;
    }
    for (i, node) in c.nodes.iter_mut().enumerate() {
        node.uplink_mbps = 500.0 + 125.0 * (i % 4) as f64;
        node.map_files_per_s = 100.0 * (1 + i % 3) as f64;
    }
    c
}

fn small_job(n: u64) -> JobSpec {
    let mut job = JobSpec::terasort(n);
    job.t = 8;
    job.keys_per_file = 16;
    job
}

fn run_report(plan: &Plan) -> NetReport {
    let mut be = NativeBackend;
    let mut exec = Executor::with_config(plan, ExecConfig::default()).expect("executor");
    let r = exec.run_batch(&mut be, plan.job.seed).expect("batch");
    assert!(r.verified);
    exec.net_report()
}

#[test]
fn prop_rack_topology_moves_exactly_the_shared_medium_bytes() {
    // Random storages, K = 2..6, random rack counts and oversubscription:
    // for every combo that builds, the rack run and the shared run agree
    // on every byte/message/round count — totals, per-node, and per-round
    // — and each rack round's makespan is bounded by its own serialized
    // fold (concurrency can only shorten a round, never grow it).
    prop::run("rack topology preserves bytes/rounds", 25, |g| {
        let k = g.usize_in(2..=6);
        let n = g.u64_in(2..=8);
        let storage: Vec<u64> = (0..k).map(|_| g.u64_in(1..=n)).collect();
        if storage.iter().sum::<u64>() < n {
            return Ok(());
        }
        let racks = g.usize_in(1..=k);
        let oversub = [1.0, 2.0, 4.0][g.usize_in(0..=2)];
        let shared_cl = cluster(&storage);
        let rack_cl = shared_cl.clone().with_topology(Topology::Rack { racks, oversub });
        let job = small_job(n);
        for placer in builtin_placers() {
            let alloc = match placer.place(&shared_cl, &job) {
                Ok(a) => a,
                Err(_) => continue, // shape not served (e.g. K=3-only)
            };
            for coder in builtin_coders() {
                let built = JobBuilder::new(&shared_cl, &job)
                    .custom_allocation(alloc.clone())
                    .coder(coder.name())
                    .mode(ShuffleMode::Coded)
                    .build();
                let shared_plan = match built {
                    Ok(p) => p,
                    Err(_) => continue, // combo rejects this shape
                };
                let rack_plan = JobBuilder::new(&rack_cl, &job)
                    .custom_allocation(alloc.clone())
                    .coder(coder.name())
                    .mode(ShuffleMode::Coded)
                    .build()
                    .map_err(|e| {
                        format!(
                            "K={k} racks={racks} {} x {}: shared built but rack failed: {e}",
                            placer.name(),
                            coder.name()
                        )
                    })?;
                let s = run_report(&shared_plan);
                let r = run_report(&rack_plan);
                let ctx = format!(
                    "K={k} storage={storage:?} racks={racks} oversub={oversub} {} x {}",
                    placer.name(),
                    coder.name()
                );
                prop::check(r.total_bytes == s.total_bytes, format!("{ctx}: total_bytes"))?;
                prop::check(r.total_msgs == s.total_msgs, format!("{ctx}: total_msgs"))?;
                prop::check(
                    r.bytes_by_node == s.bytes_by_node && r.msgs_by_node == s.msgs_by_node,
                    format!("{ctx}: per-node accounting"),
                )?;
                prop::check(r.rounds.len() == s.rounds.len(), format!("{ctx}: round count"))?;
                for (i, (rr, sr)) in r.rounds.iter().zip(&s.rounds).enumerate() {
                    prop::check(
                        rr.bytes == sr.bytes && rr.msgs == sr.msgs,
                        format!("{ctx}: round {i} bytes/msgs"),
                    )?;
                    prop::check(
                        rr.makespan_s <= rr.elapsed_s + 1e-12,
                        format!(
                            "{ctx}: round {i} makespan {} above its serialized fold {}",
                            rr.makespan_s, rr.elapsed_s
                        ),
                    )?;
                }
                // The switched report carries k access links + one trunk
                // per rack; the shared one stays link-free.
                prop::check(s.links.is_empty(), format!("{ctx}: shared links"))?;
                prop::check(
                    r.links.len() == k + racks,
                    format!("{ctx}: rack links {} != {}", r.links.len(), k + racks),
                )?;
            }
        }
        Ok(())
    });
}

/// The committed v2 plan fixture (`fixtures/plan_k3_v2.json`): a
/// hand-written K=3, N=3, sp=1 cyclic placement with one coded XOR
/// broadcast (node 0 serves nodes 1 and 2) and one uncoded delivery
/// (node 1 serves node 0) — small enough that its wire sizes and clock
/// can be recomputed here from first principles.
const FIXTURE: &str = include_str!("fixtures/plan_k3_v2.json");

#[test]
fn shared_medium_reproduces_the_fixture_clock_bit_for_bit() {
    let plan = Plan::from_json_str(FIXTURE).expect("fixture parses and revalidates");
    assert!(plan.cluster.topology.is_shared());
    assert_eq!(plan.shuffle.round_count(), 2);

    let nr = run_report(&plan);

    // Wire framing (engine/exec.rs `broadcast_sizes`): IVs are t*4 = 32
    // bytes; the coded 2-part broadcast frames 32 + 16 + 2*12 = 72 bytes,
    // the uncoded one 32 + 16 + 12 = 60.
    assert_eq!(nr.total_bytes, 72 + 60);
    assert_eq!(nr.total_msgs, 2);
    assert_eq!(nr.bytes_by_node, vec![72, 60, 0]);

    // The serialized shared-medium clock, recomputed with the exact same
    // expressions the simulator uses (`ClusterSpec::network` converts
    // Mbps/ms; `tx_time` is latency + bits/rate): any drift — a changed
    // conversion, a reordered fold, a sneaky rescheduling of Shared —
    // breaks bit-for-bit compatibility with pre-topology artifacts.
    let latency_s = 0.5 / 1e3;
    let mut expected = 0.0f64;
    for (wire, mbps) in [(72u64, 800.0f64), (60, 640.0)] {
        expected += latency_s + (wire as f64 * 8.0) / (mbps * 1e6);
    }
    assert_eq!(
        nr.elapsed_s.to_bits(),
        expected.to_bits(),
        "shared-medium clock drifted: {} != {}",
        nr.elapsed_s,
        expected
    );

    // On the shared medium the concurrent schedule *is* the serialized
    // fold — per round, bit for bit — no link ledgers, no critical group.
    assert!(nr.links.is_empty());
    for round in &nr.rounds {
        assert_eq!(round.makespan_s.to_bits(), round.elapsed_s.to_bits());
        assert_eq!(round.critical_group, None);
    }

    // And the round structure metered as committed: 72 wire bytes in
    // round 0, 60 in round 1.
    assert_eq!(nr.rounds[0].bytes, 72);
    assert_eq!(nr.rounds[1].bytes, 60);
}

#[test]
fn fixture_runs_identically_on_a_rack_topology() {
    // The same committed plan re-homed onto a 2-rack fabric (blocked
    // assignment: nodes {0, 1} in rack 0, node {2} in rack 1):
    // byte-identical counts, schedule different. Rebuilding through a
    // coder could restructure the IR, so the rack twin reruns the *same*
    // plan with only the cluster swapped through the JSON round trip.
    let shared = Plan::from_json_str(FIXTURE).unwrap();
    let rack_cl = shared
        .cluster
        .clone()
        .with_topology(Topology::Rack { racks: 2, oversub: 2.0 });
    let mut j = hetcdc::util::json::Json::parse(FIXTURE).unwrap();
    if let hetcdc::util::json::Json::Obj(m) = &mut j {
        m.insert("cluster".into(), rack_cl.to_json());
    }
    let rack_plan = Plan::from_json(&j).expect("rack fixture revalidates");
    assert_eq!(rack_plan.cluster.topology, rack_cl.topology);

    let s = run_report(&shared);
    let r = run_report(&rack_plan);
    assert_eq!(r.total_bytes, s.total_bytes);
    assert_eq!(r.total_msgs, s.total_msgs);
    assert_eq!(r.bytes_by_node, s.bytes_by_node);
    assert_eq!(r.rounds.len(), s.rounds.len());
    // 3 access links + 2 trunks.
    assert_eq!(r.links.len(), 5);
    let busy: Vec<&str> = r
        .links
        .iter()
        .filter(|l| l.msgs > 0)
        .map(|l| l.id.as_str())
        .collect();
    // Egress is sender-side: both broadcasts reach node 2 in the other
    // rack, so each occupies its sender's access link plus rack 0's
    // trunk; rack 1's trunk never carries an egress here.
    assert_eq!(busy, vec!["node0", "node1", "rack0"]);
}
