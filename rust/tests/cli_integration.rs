//! CLI integration: spawn the real `hetcdc` binary and check its output
//! contracts (exit codes, numbers, JSON mode, config files, help).

use std::process::Command;

fn hetcdc(args: &[&str]) -> (i32, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_hetcdc"))
        .args(args)
        .output()
        .expect("spawn hetcdc");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (code, stdout, _) = hetcdc(&["--help"]);
    assert_eq!(code, 0);
    for sub in ["loadstar", "place", "lp", "run", "sweep", "info"] {
        assert!(stdout.contains(sub), "help missing '{sub}'");
    }
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let (code, stdout, stderr) = hetcdc(&["frobnicate"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown subcommand"));
    assert!(stdout.contains("Usage"));
}

#[test]
fn loadstar_paper_example() {
    let (code, stdout, _) = hetcdc(&["loadstar", "--storage", "6,7,7", "--n", "12"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("L* (coded)        12"), "{stdout}");
    assert!(stdout.contains("uncoded           16"), "{stdout}");
    assert!(stdout.contains("regime            R2"), "{stdout}");
}

#[test]
fn loadstar_rejects_invalid_params() {
    let (code, _, stderr) = hetcdc(&["loadstar", "--storage", "1,1,1", "--n", "9"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("error"));
}

#[test]
fn place_prints_subset_sizes() {
    let (code, stdout, _) = hetcdc(&["place", "--storage", "6,7,7", "--n", "12"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("S{1,2}"), "{stdout}");
    assert!(stdout.contains("achievable load 12"), "{stdout}");
}

#[test]
fn lp_matches_theorem1_for_k3() {
    let (code, stdout, _) = hetcdc(&["lp", "--storage", "6,7,7", "--n", "12"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("predicted load  12"), "{stdout}");
}

#[test]
fn run_native_both_modes_verifies() {
    let (code, stdout, _) = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--mode", "both", "--backend", "native",
    ]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("load 12 IV-equations"), "{stdout}");
    assert!(stdout.contains("load 16 IV-equations"), "{stdout}");
    assert!(stdout.contains("verified=true"), "{stdout}");
}

#[test]
fn run_json_mode_emits_parseable_reports() {
    let (code, stdout, _) = hetcdc(&[
        "run", "--workload", "wordcount", "--n", "12", "--storage", "6,7,7",
        "--mode", "coded", "--backend", "native", "--json",
    ]);
    assert_eq!(code, 0, "{stdout}");
    let line = stdout.lines().find(|l| l.starts_with('{')).expect("json line");
    let j = hetcdc::util::json::Json::parse(line).expect("valid json");
    assert_eq!(j.get("load_equations").and_then(|v| v.as_f64()), Some(12.0));
    assert_eq!(j.get("verified"), Some(&hetcdc::util::json::Json::Bool(true)));
    assert_eq!(j.get("mode").and_then(|v| v.as_str()), Some("Coded"));
}

#[test]
fn run_with_cluster_config_file() {
    let dir = std::env::temp_dir().join(format!("hetcdc_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cluster.json");
    std::fs::write(
        &path,
        r#"{"nodes": [
            {"name": "a", "storage": 6, "uplink_mbps": 450},
            {"name": "b", "storage": 7, "uplink_mbps": 750},
            {"name": "c", "storage": 7, "uplink_mbps": 1000}
        ], "latency_ms": 0.1}"#,
    )
    .unwrap();
    let (code, stdout, _) = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12",
        "--config", path.to_str().unwrap(), "--mode", "coded", "--backend", "native",
    ]);
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("load 12 IV-equations"), "{stdout}");
}

#[test]
fn run_oblivious_placement_shows_penalty() {
    let (code, stdout, _) = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "4,8,12",
        "--mode", "coded", "--backend", "native", "--placement", "oblivious",
    ]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("load 24 IV-equations"), "{stdout}");
}

#[test]
fn plan_emits_valid_json_with_predicted_load() {
    let (code, stdout, _) = hetcdc(&[
        "plan", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
    ]);
    assert_eq!(code, 0, "{stdout}");
    let j = hetcdc::util::json::Json::parse(stdout.trim()).expect("valid plan json");
    assert_eq!(j.get("placer").and_then(|v| v.as_str()), Some("optimal-k3"));
    assert_eq!(j.get("coder").and_then(|v| v.as_str()), Some("pairing"));
    assert_eq!(j.get("mode").and_then(|v| v.as_str()), Some("coded"));
    assert_eq!(
        j.get("predicted").and_then(|p| p.get("load_equations")).and_then(|v| v.as_f64()),
        Some(12.0)
    );
    // The emitted artifact is a loadable, re-validated plan.
    let plan = hetcdc::engine::Plan::from_json_str(stdout.trim()).expect("plan loads");
    assert_eq!(plan.predicted.load_equations, 12.0);
}

#[test]
fn plan_file_roundtrips_through_run_with_batches() {
    let dir = std::env::temp_dir().join(format!("hetcdc_plan_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    let (code, stdout, _) = hetcdc(&[
        "plan", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--mode", "coded", "--out", path.to_str().unwrap(),
    ]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("predicted load 12"), "{stdout}");

    let (code, stdout, _) = hetcdc(&[
        "run", "--plan", path.to_str().unwrap(), "--batches", "2", "--json",
    ]);
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(code, 0, "{stdout}");
    let loads: Vec<f64> = stdout
        .lines()
        .filter(|l| l.starts_with('{'))
        .map(|l| {
            let j = hetcdc::util::json::Json::parse(l).expect("report json");
            assert_eq!(j.get("verified"), Some(&hetcdc::util::json::Json::Bool(true)));
            j.get("load_equations").and_then(|v| v.as_f64()).unwrap()
        })
        .collect();
    assert_eq!(loads, vec![12.0, 12.0], "two batches, identical loads");
}

#[test]
fn run_plan_rejects_conflicting_flags() {
    let dir = std::env::temp_dir().join(format!("hetcdc_conflict_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    let (code, _, _) = hetcdc(&[
        "plan", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--out", path.to_str().unwrap(),
    ]);
    assert_eq!(code, 0);
    let (code, _, stderr) = hetcdc(&[
        "run", "--plan", path.to_str().unwrap(), "--mode", "uncoded",
    ]);
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(code, 1);
    assert!(stderr.contains("conflicts with --plan"), "{stderr}");
}

#[test]
fn plan_and_run_combinatorial_grid_at_k8() {
    // K=8 grid (q=2, r=4): uncoded load would be 32 IV-equations
    // (16 subfiles x 4 missing nodes / sp 2); the combinatorial coder's
    // gain r−1 = 3 brings it to 32/3.
    let (code, stdout, _) = hetcdc(&[
        "plan", "--workload", "terasort", "--n", "8",
        "--storage", "4,4,5,5,6,6,7,7", "--placement", "combinatorial",
    ]);
    assert_eq!(code, 0, "{stdout}");
    let j = hetcdc::util::json::Json::parse(stdout.trim()).expect("valid plan json");
    assert_eq!(j.get("placer").and_then(|v| v.as_str()), Some("combinatorial"));
    assert_eq!(j.get("coder").and_then(|v| v.as_str()), Some("combinatorial"));
    let load = j
        .get("predicted")
        .and_then(|p| p.get("load_equations"))
        .and_then(|v| v.as_f64())
        .unwrap();
    assert!((load - 32.0 / 3.0).abs() < 1e-9, "load {load}");
    // Multi-round IR serializes with round structure (schema v2).
    let rounds = j
        .get("shuffle")
        .and_then(|s| s.get("rounds"))
        .and_then(|r| r.as_arr())
        .expect("round-structured shuffle");
    assert_eq!(rounds.len(), 8);

    let (code, stdout, _) = hetcdc(&[
        "run", "--workload", "terasort", "--n", "8",
        "--storage", "4,4,5,5,6,6,7,7", "--mode", "coded",
        "--backend", "native", "--placement", "combinatorial",
    ]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("verified=true"), "{stdout}");
}

#[test]
fn run_rejects_unknown_placement_with_typed_error() {
    let (code, _, stderr) = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--mode", "coded", "--placement", "frobnicate",
    ]);
    assert_eq!(code, 1);
    assert!(stderr.contains("unknown placer"), "{stderr}");
}

#[test]
fn sweep_emits_markdown_table() {
    let (code, stdout, _) = hetcdc(&["sweep", "--n", "6", "--step", "3"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("| M1 | M2 | M3 |"));
    assert!(stdout.lines().filter(|l| l.starts_with('|')).count() > 3);
}

#[test]
fn bad_config_file_is_a_clean_error() {
    let (code, _, stderr) = hetcdc(&[
        "run", "--config", "/nonexistent/cluster.json", "--workload", "terasort",
    ]);
    assert_eq!(code, 1);
    assert!(stderr.contains("error"));
}

#[test]
fn run_threads_knob_reproduces_serial_loads() {
    let serial = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--mode", "coded", "--backend", "native", "--json", "--threads", "1",
    ]);
    let parallel = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--mode", "coded", "--backend", "native", "--json", "--threads", "3",
    ]);
    assert_eq!(serial.0, 0, "{}", serial.1);
    assert_eq!(parallel.0, 0, "{}", parallel.1);
    let report = |out: &str| {
        let line = out.lines().find(|l| l.starts_with('{')).expect("json line").to_string();
        hetcdc::util::json::Json::parse(&line).expect("valid json")
    };
    let (a, b) = (report(&serial.1), report(&parallel.1));
    for field in ["load_equations", "payload_bytes", "wire_bytes", "messages", "shuffle_time_s"] {
        assert_eq!(a.get(field), b.get(field), "field {field} differs across --threads");
    }
}

#[test]
fn run_threads_auto_falls_back_cleanly() {
    // --threads 0 = auto-detect. Auto must never error: when the host's
    // parallelism cannot be queried the executor degrades to one worker,
    // and either way the results equal the serial reference.
    let (code, stdout, stderr) = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--mode", "coded", "--backend", "native", "--json", "--threads", "0",
    ]);
    assert_eq!(code, 0, "--threads 0 must not error\n{stdout}\n{stderr}");
    let line = stdout.lines().find(|l| l.starts_with('{')).expect("json line");
    let j = hetcdc::util::json::Json::parse(line).expect("valid json");
    assert_eq!(j.get("load_equations").and_then(|v| v.as_f64()), Some(12.0));
    assert_eq!(j.get("verified"), Some(&hetcdc::util::json::Json::Bool(true)));
}

#[test]
fn run_pipeline_matches_serial_batches() {
    // --pipeline overlaps Map of batch i+1 with Shuffle of batch i; the
    // per-batch JSON reports must be bit-identical to the serial run on
    // every deterministic field.
    let serial = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--mode", "coded", "--backend", "native", "--json", "--batches", "3",
    ]);
    let piped = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--mode", "coded", "--backend", "native", "--json", "--batches", "3",
        "--pipeline",
    ]);
    assert_eq!(serial.0, 0, "{}\n{}", serial.1, serial.2);
    assert_eq!(piped.0, 0, "{}\n{}", piped.1, piped.2);
    let reports = |out: &str| -> Vec<hetcdc::util::json::Json> {
        out.lines()
            .filter(|l| l.starts_with('{'))
            .map(|l| hetcdc::util::json::Json::parse(l).expect("report json"))
            .collect()
    };
    let (a, b) = (reports(&serial.1), reports(&piped.1));
    assert_eq!(a.len(), 3, "{}", serial.1);
    assert_eq!(b.len(), 3, "{}", piped.1);
    for (batch, (ra, rb)) in a.iter().zip(&b).enumerate() {
        assert_eq!(rb.get("verified"), Some(&hetcdc::util::json::Json::Bool(true)));
        for field in [
            "seed", "load_equations", "payload_bytes", "wire_bytes", "messages",
            "map_time_s", "shuffle_time_s", "max_abs_err",
        ] {
            assert_eq!(
                ra.get(field),
                rb.get(field),
                "field {field} differs in batch {batch} under --pipeline"
            );
        }
    }
}

#[test]
fn plan_with_threads_certifies_parallel_execution() {
    let (code, stdout, stderr) = hetcdc(&[
        "plan", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--threads", "2",
    ]);
    assert_eq!(code, 0, "{stdout}\n{stderr}");
    assert!(stderr.contains("certified for parallel execution"), "{stderr}");
    // The plan JSON still lands on stdout, untouched by certification.
    assert!(hetcdc::engine::Plan::from_json_str(stdout.trim()).is_ok());
}

#[test]
fn plan_threads_emit_byte_identical_artifacts() {
    // --threads now drives plan CONSTRUCTION too; the serialized plan
    // must be byte-equal at every worker count (K=8 combinatorial grid
    // exercises the parallel coder + decoder paths).
    let storage = "4,4,5,5,6,6,7,7";
    let base = hetcdc(&[
        "plan", "--workload", "terasort", "--n", "8", "--storage", storage,
        "--placement", "combinatorial",
    ]);
    assert_eq!(base.0, 0, "{}\n{}", base.1, base.2);
    for threads in ["2", "0"] {
        let t = hetcdc(&[
            "plan", "--workload", "terasort", "--n", "8", "--storage", storage,
            "--placement", "combinatorial", "--threads", threads,
        ]);
        assert_eq!(t.0, 0, "--threads {threads}: {}\n{}", t.1, t.2);
        assert_eq!(base.1, t.1, "plan JSON differs at --threads {threads}");
    }
}

#[test]
fn lp_cap_flag_reaches_the_placer_and_warns() {
    // A cap of 1 truncates the K=4 enumeration on the legacy capped
    // route: the plan must build, carry dropped_collections, and warn
    // on stderr.
    let (code, stdout, stderr) = hetcdc(&[
        "plan", "--workload", "terasort", "--n", "8", "--storage", "3,4,5,6",
        "--placement", "lp-capped", "--lp-cap", "1",
    ]);
    assert_eq!(code, 0, "{stdout}\n{stderr}");
    assert!(stderr.contains("collection"), "expected a cap warning: {stderr}");
    assert!(stdout.contains("dropped_collections"), "{stdout}");
    // The exact default outgrows the same cap: no truncation, no
    // warning, and the certified solver counters land in the artifact.
    let (code, stdout, stderr) = hetcdc(&[
        "plan", "--workload", "terasort", "--n", "8", "--storage", "3,4,5,6",
        "--placement", "lp-general", "--lp-cap", "1",
    ]);
    assert_eq!(code, 0, "{stdout}\n{stderr}");
    assert!(!stdout.contains("dropped_collections"), "{stdout}");
    assert!(stdout.contains("\"lp_solver\""), "{stdout}");
    assert!(stdout.contains("\"certified\": true"), "{stdout}");
    // --lp-cap conflicts with --plan (the plan already fixes placement).
    let (code, _, stderr) = hetcdc(&[
        "run", "--plan", "/nonexistent/plan.json", "--lp-cap", "64",
    ]);
    assert_eq!(code, 1);
    assert!(stderr.contains("conflicts with --plan"), "{stderr}");
}

#[test]
fn bench_json_check_armed_distinguishes_pending_from_blessed() {
    let dir = std::env::temp_dir().join(format!("hetcdc_armed_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let pending = dir.join("pending.json");
    std::fs::write(&pending, r#"{"schema": 1, "scenarios": []}"#).unwrap();
    let (code, _, stderr) = hetcdc(&[
        "bench-json", "--check-armed", "--baseline", pending.to_str().unwrap(),
    ]);
    assert_eq!(code, 3, "pending placeholder must exit 3: {stderr}");
    assert!(stderr.contains("DISARMED"), "{stderr}");

    let blessed = dir.join("blessed.json");
    std::fs::write(&blessed, r#"{"schema": 1, "scenarios": [{"name": "x"}]}"#).unwrap();
    let (code, stdout, _) = hetcdc(&[
        "bench-json", "--check-armed", "--baseline", blessed.to_str().unwrap(),
    ]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("armed"), "{stdout}");

    let malformed = dir.join("malformed.json");
    std::fs::write(&malformed, r#"{"schema": 1}"#).unwrap();
    let (code, _, stderr) = hetcdc(&[
        "bench-json", "--check-armed", "--baseline", malformed.to_str().unwrap(),
    ]);
    assert_eq!(code, 1, "malformed baseline must fail: {stderr}");

    let (code, _, stderr) = hetcdc(&["bench-json", "--check-armed"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("--baseline"), "{stderr}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_json_emits_deterministic_artifact_and_self_compares() {
    let dir = std::env::temp_dir().join(format!("hetcdc_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out1 = dir.join("bench1.json");
    let out2 = dir.join("bench2.json");

    let (code, stdout, stderr) = hetcdc(&["bench-json", "--out", out1.to_str().unwrap()]);
    assert_eq!(code, 0, "{stdout}\n{stderr}");
    let text1 = std::fs::read_to_string(&out1).unwrap();
    let j = hetcdc::util::json::Json::parse(&text1).expect("valid bench json");
    assert_eq!(j.get("schema").and_then(|v| v.as_usize()), Some(1));
    let scenarios = j.get("scenarios").and_then(|s| s.as_arr()).expect("scenarios");
    assert!(scenarios.len() >= 6, "expected the full K∈{{3,5,8}} suite");
    assert!(j.get("totals").and_then(|t| t.get("payload_bytes")).is_some());

    // Determinism: a second run emits byte-identical JSON.
    let (code, _, _) = hetcdc(&["bench-json", "--out", out2.to_str().unwrap(), "--threads", "2"]);
    assert_eq!(code, 0);
    let text2 = std::fs::read_to_string(&out2).unwrap();
    assert_eq!(text1, text2, "bench artifact must be run- and thread-invariant");

    // Gating against itself passes; against a doctored (smaller) baseline fails.
    let (code, stdout, _) = hetcdc(&[
        "bench-json", "--out", out2.to_str().unwrap(),
        "--baseline", out1.to_str().unwrap(),
    ]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("baseline gate PASSED"), "{stdout}");

    let doctored = dir.join("baseline_small.json");
    std::fs::write(&doctored, text1.replace("\"payload_bytes\"", "\"payload_bytes_was\"")).unwrap();
    let (code, stdout, stderr) = hetcdc(&[
        "bench-json", "--out", out2.to_str().unwrap(),
        "--baseline", doctored.to_str().unwrap(),
    ]);
    assert_eq!(code, 1, "{stdout}\n{stderr}");
    assert!(stderr.contains("baseline gate FAILED"), "{stderr}");

    // A pending (empty) baseline disarms the gate instead of failing —
    // but loudly: an explicit stderr warning, never a silent pass.
    let pending = dir.join("baseline_pending.json");
    std::fs::write(&pending, r#"{"schema": 1, "scenarios": []}"#).unwrap();
    let (code, stdout, stderr) = hetcdc(&[
        "bench-json", "--out", out2.to_str().unwrap(),
        "--baseline", pending.to_str().unwrap(),
    ]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("baseline gate PENDING"), "{stdout}");
    assert!(
        stderr.contains("WARNING") && stderr.contains("DISARMED"),
        "pending baseline must warn explicitly, got: {stderr}"
    );

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn subcommand_help_agrees_on_shared_flags() {
    // `plan`, `run`, and `bench-json` flatten the same `common::` specs
    // into their option tables; their help outputs must never drift
    // apart on a shared flag (same usage line, same description, same
    // default).
    let (pc, plan_help, _) = hetcdc(&["plan", "--help"]);
    let (rc, run_help, _) = hetcdc(&["run", "--help"]);
    let (bc, bench_help, _) = hetcdc(&["bench-json", "--help"]);
    assert_eq!((pc, rc, bc), (0, 0, 0));
    let block = |help: &str, flag: &str| -> String {
        let head = format!("  --{flag}");
        let mut lines = help.lines();
        while let Some(l) = lines.next() {
            if l == head || l.starts_with(&format!("{head} ")) {
                let desc = lines.next().unwrap_or_default();
                return format!("{l}\n{desc}");
            }
        }
        panic!("--{flag} missing from help:\n{help}");
    };
    // plan and run share the whole planning option set.
    for flag in ["threads", "placement", "coder", "lp-cap", "topology", "faults", "help"] {
        assert_eq!(
            block(&plan_help, flag),
            block(&run_help, flag),
            "--{flag} drifted between `plan` and `run` help"
        );
    }
    // bench-json shares the exploration overrides (it keeps its own
    // --threads: the default there is 0 = auto, not 1 = serial).
    for flag in ["topology", "faults", "help"] {
        assert_eq!(
            block(&plan_help, flag),
            block(&bench_help, flag),
            "--{flag} drifted between `plan` and `bench-json` help"
        );
    }
    assert_ne!(
        block(&plan_help, "threads"),
        block(&bench_help, "threads"),
        "bench-json --threads is deliberately its own spec (default 0 = auto)"
    );
}

#[test]
fn faults_flag_reaches_the_planner_and_conflicts_with_plan_files() {
    // A straggle spec shifts only the schedule: the run still verifies
    // with the same IV-equation load.
    let (code, stdout, _) = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--mode", "coded", "--backend", "native",
        "--faults", "straggle:seed=7,amp=4",
    ]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("load 12 IV-equations"), "{stdout}");
    assert!(stdout.contains("verified=true"), "{stdout}");
    // The fault spec lands in the emitted plan artifact and round-trips.
    let (code, stdout, _) = hetcdc(&[
        "plan", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--faults", "repair:f=1",
    ]);
    assert_eq!(code, 0, "{stdout}");
    let plan = hetcdc::engine::Plan::from_json_str(stdout.trim()).expect("faulted plan loads");
    assert_eq!(plan.cluster.faults.repair, 1);
    // Bad specs die with a typed error, not a panic.
    let (code, _, stderr) = hetcdc(&[
        "run", "--workload", "terasort", "--n", "12", "--storage", "6,7,7",
        "--faults", "straggle:amp=nope",
    ]);
    assert_eq!(code, 1);
    assert!(stderr.contains("error"), "{stderr}");
    // A plan file already fixes the fault model: --faults conflicts.
    let (code, _, stderr) = hetcdc(&[
        "run", "--plan", "/nonexistent/plan.json", "--faults", "repair:f=1",
    ]);
    assert_eq!(code, 1);
    assert!(stderr.contains("conflicts with --plan"), "{stderr}");
}

#[test]
fn verify_subcommand_passes_with_lp() {
    let (code, stdout, _) = hetcdc(&["verify", "--n", "6", "--lp"]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("verify OK"), "{stdout}");
    assert!(stdout.contains("LP == Theorem 1"), "{stdout}");
}
