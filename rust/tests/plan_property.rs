//! Property tests for the staged prepared-plan API: every `Placer` ×
//! `ShuffleCoder` combination that builds a `Plan` must build a
//! *decoder-complete* one (build verifies decodability; we cross-check
//! with the symbolic decoder), across randomized heterogeneous storages
//! for K = 2..6 — and executing one `Plan` twice must reproduce the exact
//! same loads.

use hetcdc::coding::{builtin_coders, decoder, ShuffleCoder};
use hetcdc::engine::{ExecConfig, Executor, JobBuilder, NativeBackend};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::placement::{builtin_placers, Placer};
use hetcdc::prop;
use hetcdc::HetcdcError;

fn cluster(storage: &[u64]) -> ClusterSpec {
    let mut c = ClusterSpec::homogeneous(storage.len(), 1, 1000.0);
    for (node, &m) in c.nodes.iter_mut().zip(storage) {
        node.storage = m;
    }
    c
}

fn small_job(n: u64) -> JobSpec {
    let mut job = JobSpec::terasort(n);
    job.t = 8;
    job.keys_per_file = 16;
    job
}

#[test]
fn prop_every_placer_coder_combo_builds_decodable_plans() {
    // Random heterogeneous storages, K = 2..6. A combo may reject a shape
    // with a typed error (homogeneous placer on unequal storage, the
    // multicast coder on an irregular allocation, K=3-only placers, ...);
    // every combo that *accepts* must produce a plan that decodes and
    // whose predicted load does not exceed the uncoded baseline.
    prop::run("placer x coder -> decodable plan", 40, |g| {
        let k = g.usize_in(2..=6);
        let n = g.u64_in(2..=8);
        let storage: Vec<u64> = (0..k).map(|_| g.u64_in(1..=n)).collect();
        if storage.iter().sum::<u64>() < n {
            return Ok(()); // cannot cover N: every placer rejects
        }
        let cl = cluster(&storage);
        let job = small_job(n);
        for placer in builtin_placers() {
            // Place once per strategy; fan every coder over the result.
            let alloc = match placer.place(&cl, &job) {
                Ok(a) => a,
                Err(_) => continue, // shape not served (e.g. K=3-only)
            };
            for coder in builtin_coders() {
                let built = JobBuilder::new(&cl, &job)
                    .custom_allocation(alloc.clone())
                    .coder(coder.name())
                    .mode(ShuffleMode::Coded)
                    .build();
                let plan = match built {
                    Ok(plan) => plan,
                    // Shape not served by this combo: fine, but it must
                    // never be the "plan built yet undecodable" error —
                    // that would mean validation was skipped.
                    Err(HetcdcError::Undecodable { .. }) => {
                        return prop::fail(format!(
                            "K={k} storage={storage:?} N={n}: {} x {} built an \
                             undecodable plan",
                            placer.name(),
                            coder.name()
                        ));
                    }
                    Err(_) => continue,
                };
                let report = decoder::verify(&plan.alloc, &plan.shuffle);
                if !report.is_complete() {
                    return prop::fail(format!(
                        "K={k} storage={storage:?} N={n}: {} x {} plan passed build \
                         but fails symbolic decode",
                        placer.name(),
                        coder.name()
                    ));
                }
                if plan.predicted.load_equations > plan.predicted.uncoded_equations + 1e-9 {
                    return prop::fail(format!(
                        "K={k} storage={storage:?} N={n}: {} x {} coded load {} exceeds \
                         uncoded {}",
                        placer.name(),
                        coder.name(),
                        plan.predicted.load_equations,
                        plan.predicted.uncoded_equations
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_built_plans_execute_verified_across_k() {
    // End-to-end: any plan the default (auto) pipeline builds must run
    // verified, with measured load equal to the build-time prediction.
    prop::run("plan executes verified", 12, |g| {
        let k = g.usize_in(2..=5);
        let n = g.u64_in(2..=6);
        let storage: Vec<u64> = (0..k).map(|_| g.u64_in(1..=n)).collect();
        if storage.iter().sum::<u64>() < n {
            return Ok(());
        }
        let cl = cluster(&storage);
        let job = small_job(n);
        let plan = match JobBuilder::new(&cl, &job).build() {
            Ok(p) => p,
            Err(e) => return prop::fail(format!("K={k} storage={storage:?} N={n}: {e}")),
        };
        let mut be = NativeBackend;
        let r = Executor::with_config(&plan, ExecConfig::default())
            .and_then(|mut exec| exec.run(&mut be))
            .map_err(|e| format!("K={k} storage={storage:?} N={n}: {e}"))?;
        prop::check(
            r.verified && (r.load_equations - plan.predicted.load_equations).abs() < 1e-9,
            format!(
                "K={k} storage={storage:?} N={n}: verified={} measured={} predicted={}",
                r.verified, r.load_equations, plan.predicted.load_equations
            ),
        )
    });
}

#[test]
fn two_executor_runs_of_one_plan_produce_identical_loads() {
    let cl = cluster(&[4, 8, 12]);
    let job = small_job(12);
    let plan = JobBuilder::new(&cl, &job).placer("optimal-k3").build().unwrap();
    let mut be = NativeBackend;
    let mut exec = Executor::with_config(&plan, ExecConfig::default()).unwrap();
    let a = exec.run_batch(&mut be, 7).unwrap();
    let b = exec.run_batch(&mut be, 99).unwrap();
    assert!(a.verified && b.verified);
    assert_eq!(a.load_equations, b.load_equations);
    assert_eq!(a.plan_equations, b.plan_equations);
    assert_eq!(a.payload_bytes, b.payload_bytes);
    assert_eq!(a.wire_bytes, b.wire_bytes);
    assert_eq!(a.messages, b.messages);
    assert_eq!(a.map_time_s, b.map_time_s);
    assert_eq!(a.shuffle_time_s, b.shuffle_time_s);
    // And both equal the plan's build-time prediction.
    assert_eq!(a.load_equations, plan.predicted.load_equations);
    assert_eq!(a.payload_bytes, plan.predicted.payload_bytes);
    assert_eq!(a.wire_bytes, plan.predicted.wire_bytes);
    assert_eq!(a.shuffle_time_s, plan.predicted.shuffle_time_s);
    assert_eq!(a.map_time_s, plan.predicted.map_time_s);
}

#[test]
fn combinatorial_grid_plan_json_is_byte_identical_across_builds() {
    // Guards the BTreeMap-backed lattice bookkeeping in the combinatorial
    // coder (`xtask lint` rule `unordered-iter`): two independent builds
    // of the same grid plan must serialize to identical bytes.
    let cl = cluster(&[4, 4, 4, 4, 4, 4, 4, 4]);
    let job = small_job(8);
    let build = || {
        JobBuilder::new(&cl, &job)
            .placer("combinatorial")
            .mode(ShuffleMode::Coded)
            .build()
            .expect("grid plan build")
    };
    let (a, b) = (build(), build());
    assert_eq!(a.fingerprint, b.fingerprint);
    assert_eq!(a.to_json_string(), b.to_json_string());
    // And the plan actually runs verified.
    let mut be = NativeBackend;
    let r = Executor::with_config(&a, ExecConfig::default())
        .unwrap()
        .run(&mut be)
        .unwrap();
    assert!(r.verified);
}

#[test]
fn engine_plan_panic_paths_are_typed_errors() {
    // The old enum-matched Engine indexed holders[0] and unwrap()ed
    // min() on storage; both paths must now be typed errors.
    use hetcdc::coding::coder_by_name;
    use hetcdc::placement::{placer_by_name, Allocation};
    let empty = ClusterSpec {
        nodes: vec![],
        latency_ms: 0.0,
        topology: hetcdc::net::Topology::Shared,
        faults: hetcdc::net::FaultSpec::default(),
    };
    let job = small_job(12);
    let err = placer_by_name("oblivious", &empty)
        .unwrap()
        .place(&empty, &job)
        .unwrap_err();
    assert!(matches!(err, HetcdcError::InvalidParams(_)), "{err}");

    let cl = cluster(&[6, 7, 7]);
    let no_subfiles = Allocation::new(3, 1, vec![]);
    let err = coder_by_name("multicast")
        .unwrap()
        .plan(&cl, &job, &no_subfiles)
        .unwrap_err();
    assert!(matches!(err, HetcdcError::InvalidPlacement(_)), "{err}");

    // And through the full pipeline: a zero-file job is InvalidJob, not a
    // panic somewhere inside placement.
    let zero = JobSpec::terasort(0);
    let err = JobBuilder::new(&cl, &zero).build().unwrap_err();
    assert!(matches!(err, HetcdcError::InvalidJob(_)), "{err}");
}
