//! E4/E5 — the §V linear-programming algorithm.
//!
//! E4 (Remark 5): for K=3 the LP reproduces Theorem 1 with no regime
//! case-split — verified on an exhaustive grid.
//! E5 (§V-B): the K=4 example and heterogeneous K=4/5 instances — LP
//! predicted load vs uncoded vs the engine's executed (greedy-pairing)
//! load on the realized allocation.

use hetcdc::bench::{bench_fn, section, table, Bench};
use hetcdc::coding::plan::{plan_greedy, plan_uncoded};
use hetcdc::placement::lp_general::{
    allocation_from_solution, solve_general, DEFAULT_COLLECTION_CAP,
};
use hetcdc::theory::load;
use hetcdc::theory::params::{Params3, ParamsK};

fn main() {
    section("E4: Remark 5 — LP(K=3) == Theorem 1 (exhaustive grid, N=8)");
    let n = 8u64;
    let mut points = 0u64;
    let mut max_dev = 0f64;
    for m1 in 1..=n {
        for m2 in m1..=n {
            for m3 in m2..=n {
                let Ok(p3) = Params3::new(m1, m2, m3, n) else {
                    continue;
                };
                let pk = ParamsK::new(vec![m1, m2, m3], n).unwrap();
                let sol = solve_general(&pk, DEFAULT_COLLECTION_CAP).expect("LP");
                let dev = (sol.load - load::lstar(&p3)).abs();
                max_dev = max_dev.max(dev);
                assert!(
                    dev < 1e-6,
                    "{p3}: LP {} != L* {}",
                    sol.load,
                    load::lstar(&p3)
                );
                points += 1;
            }
        }
    }
    println!("LP == L* on all {points} grid points (max |dev| = {max_dev:.2e})");

    section("E5: §V-B — K=4 example and heterogeneous instances");
    let cases: Vec<(Vec<u64>, u64, &str)> = vec![
        (vec![5, 5, 5, 5], 10, "K=4 homogeneous r=2 ([2]: L = 10)"),
        (vec![3, 5, 6, 8], 12, "K=4 heterogeneous"),
        (vec![2, 4, 6, 8, 10], 12, "K=5 heterogeneous"),
        (vec![4, 4, 6, 6], 10, "K=4 two-tier"),
        (vec![3, 3, 3, 3, 3], 5, "K=5 homogeneous r=3"),
    ];
    let mut rows = Vec::new();
    for (m, n, label) in &cases {
        let pk = ParamsK::new(m.clone(), *n).unwrap();
        let k = pk.k();
        let sol = solve_general(&pk, DEFAULT_COLLECTION_CAP).expect("LP");
        let alloc = allocation_from_solution(&pk, &sol);
        alloc.validate(m, *n).expect("realized allocation valid");
        let executed = plan_greedy(&alloc).load_equations(&alloc);
        let uncoded_alloc = plan_uncoded(&alloc).load_equations(&alloc);
        let uncoded_best = (k as u64 * n - pk.total()) as f64;
        rows.push(vec![
            label.to_string(),
            format!("{:?} N={n}", m),
            format!("{:.2}", sol.load),
            format!("{executed:.2}"),
            format!("{uncoded_alloc:.2}"),
            format!("{uncoded_best:.2}"),
        ]);
        assert!(sol.load <= uncoded_best + 1e-6, "{label}: LP worse than uncoded");
        assert!(executed <= uncoded_alloc + 1e-9, "{label}: coding never helps?!");
    }
    table(
        &[
            "case",
            "storage",
            "LP predicted L",
            "engine greedy L",
            "uncoded (same alloc)",
            "uncoded (best alloc)",
        ],
        &rows,
    );
    println!(
        "\nnote: 'engine greedy' executes pair-XORs only; for 1<j<K−1 subsystems the LP's\n\
         (1−1/j)-factor collections are a prediction per the paper's Step 6 (DESIGN.md §9)."
    );

    section("timing");
    let cfg = Bench::default();
    let p3 = ParamsK::new(vec![6, 7, 7], 12).unwrap();
    let p4 = ParamsK::new(vec![3, 5, 6, 8], 12).unwrap();
    let p5 = ParamsK::new(vec![2, 4, 6, 8, 10], 12).unwrap();
    bench_fn("solve_general K=3", &cfg, || {
        solve_general(&p3, DEFAULT_COLLECTION_CAP).unwrap().load
    });
    bench_fn("solve_general K=4", &cfg, || {
        solve_general(&p4, DEFAULT_COLLECTION_CAP).unwrap().load
    });
    bench_fn("solve_general K=5", &cfg, || {
        solve_general(&p5, DEFAULT_COLLECTION_CAP).unwrap().load
    });
}
