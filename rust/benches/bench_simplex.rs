//! E9 — §V LP scaling (Remark 7): problem size, pivot counts, and solve
//! time as K grows, plus raw simplex throughput on random LPs.

use hetcdc::bench::{bench_fn, section, table, Bench};
use hetcdc::lp::{solve, Cmp, Lp};
use hetcdc::placement::lp_general::{build_lp, perfect_collections, solve_general};
use hetcdc::theory::params::ParamsK;
use hetcdc::util::rng::Xoshiro256;
use std::time::Instant;

fn main() {
    section("E9: §V LP size and solve time vs K (Remark 7)");
    let cap = 4096;
    let mut rows = Vec::new();
    for k in 3..=6usize {
        // Heterogeneous storage ramp covering N.
        let n = 12u64;
        let m: Vec<u64> = (0..k).map(|i| 3 + (i as u64 * 7) % (n - 3)).collect();
        let p = match ParamsK::new(m.clone(), n) {
            Ok(p) => p,
            Err(_) => continue,
        };
        let model = build_lp::<f64>(&p, cap);
        let t0 = Instant::now();
        let sol = solve_general(&p, cap).expect("LP solve");
        let dt = t0.elapsed();
        let colls: usize = (2..k.saturating_sub(1))
            .map(|j| perfect_collections(k, j, cap).0.len())
            .sum();
        rows.push(vec![
            k.to_string(),
            format!("{m:?}"),
            model.lp.n_vars.to_string(),
            model.lp.constraints.len().to_string(),
            colls.to_string(),
            sol.pivots.to_string(),
            format!("{:.2?}", dt),
            format!("{:.2}", sol.load),
        ]);
    }
    table(
        &["K", "storage", "vars", "constraints", "collections", "pivots", "time", "load"],
        &rows,
    );

    section("perfect-collection enumeration");
    let cfg = Bench::default();
    for (k, j) in [(4usize, 2usize), (5, 2), (6, 2), (6, 3)] {
        let (colls, dropped) = perfect_collections(k, j, cap);
        println!("C'_{j} for K={k}: {} collections (dropped {dropped})", colls.len());
        bench_fn(&format!("enumerate C'_{j} K={k}"), &cfg, || {
            perfect_collections(k, j, cap).0.len()
        });
    }

    section("raw simplex throughput (random dense LPs)");
    let mut rng = Xoshiro256::seed_from_u64(7);
    for (nv, nc) in [(10usize, 8usize), (30, 25), (60, 50)] {
        let mut lp: Lp<f64> = Lp::new();
        for v in 0..nv {
            lp.add_var(format!("v{v}"), (rng.gen_range(9) as f64) - 4.0);
        }
        for _ in 0..nc {
            let mut coeffs: Vec<(usize, f64)> = Vec::new();
            for v in 0..nv {
                if rng.gen_range(3) == 0 {
                    coeffs.push((v, (rng.gen_range(7) as f64) - 3.0));
                }
            }
            if coeffs.is_empty() {
                continue;
            }
            lp.constrain(coeffs, Cmp::Le, rng.gen_range(40) as f64);
        }
        for v in 0..nv {
            lp.constrain(vec![(v, 1.0)], Cmp::Le, 25.0);
        }
        bench_fn(&format!("simplex {nv} vars x {nc} rows"), &cfg, || {
            solve(&lp).map(|s| s.pivots).unwrap_or(0)
        });
    }
}
