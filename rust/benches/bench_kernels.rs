//! E8 — primitive throughput: the XOR hot path, shuffle plan
//! construction/decoding, and PJRT artifact execution latency.

use hetcdc::bench::{bench_fn, section, Bench};
use hetcdc::coding::plan::plan_k3;
use hetcdc::coding::xor::xor_into;
use hetcdc::engine::exec::{execute_shuffle, NodeState};
use hetcdc::coding::plan::IvId;
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::placement::k3::optimal_allocation;
use hetcdc::runtime::Runtime;
use hetcdc::theory::params::Params3;
use hetcdc::util::rng::Xoshiro256;

fn main() {
    section("E8: XOR combine throughput (the coded-shuffle hot path)");
    let cfg = Bench::default();
    let mut rng = Xoshiro256::seed_from_u64(1);
    for size in [128usize, 1024, 16 * 1024, 256 * 1024, 4 * 1024 * 1024] {
        let src: Vec<u8> = (0..size).map(|_| rng.next_u64() as u8).collect();
        let mut dst: Vec<u8> = (0..size).map(|_| rng.next_u64() as u8).collect();
        let r = bench_fn(&format!("xor_into {size} B"), &cfg, || {
            xor_into(&mut dst, &src);
            dst[0]
        });
        println!(
            "    -> {:.2} GiB/s",
            size as f64 / (r.mean_ns / 1e9) / (1024.0 * 1024.0 * 1024.0)
        );
    }

    section("shuffle plan construction + byte-level execution");
    let p = Params3::new(60, 70, 70, 120).unwrap();
    let alloc = optimal_allocation(&p);
    bench_fn("plan_k3 (N=120, 240 subfiles)", &cfg, || plan_k3(&alloc));
    let plan = plan_k3(&alloc);
    let iv_bytes = 128usize;
    let cluster = ClusterSpec::homogeneous(3, 1, 1000.0);
    bench_fn("execute_shuffle (240 subfiles, 128B IVs)", &cfg, || {
        let mut states: Vec<NodeState> = (0..3)
            .map(|_| NodeState::new(3, alloc.n_sub(), iv_bytes))
            .collect();
        // Seed sender knowledge with synthetic payloads.
        for (sub, &h) in alloc.holders.iter().enumerate() {
            for node in 0..3 {
                if h & (1 << node) != 0 {
                    for g in 0..3 {
                        states[node].set_full(
                            IvId { group: g, sub },
                            vec![(sub as u8) ^ (g as u8); iv_bytes],
                        );
                    }
                }
            }
        }
        let mut net = cluster.network().expect("network");
        execute_shuffle(&plan, &mut states, &mut net)
            .unwrap()
            .payload_bytes
    });

    match Runtime::load(Runtime::default_dir()) {
        Ok(mut rt) => {
            section("PJRT artifact execution latency (CPU client)");
            let m = rt.manifest.clone();
            rt.precompile(&["map_project", "map_histogram", "reduce_sum", "xor_blocks"])
                .expect("precompile");
            let qt = m.q * m.t;
            let w: Vec<f32> = (0..qt * m.vocab).map(|i| (i % 17) as f32 / 8.0).collect();
            let c: Vec<f32> = (0..m.vocab * m.map_batch).map(|i| (i % 5) as f32).collect();
            let wl = Runtime::lit_f32(&w, &[qt, m.vocab]).unwrap();
            let cl = Runtime::lit_f32(&c, &[m.vocab, m.map_batch]).unwrap();
            let r = bench_fn("map_project (96x256 @ 256x16)", &cfg, || {
                rt.execute_to_f32("map_project", &[wl.clone(), cl.clone()]).unwrap()
            });
            let flops = 2.0 * qt as f64 * m.vocab as f64 * m.map_batch as f64;
            println!("    -> {:.2} GFLOP/s", flops / r.mean_ns);

            let keys: Vec<i32> = (0..m.map_batch * m.keys_per_file)
                .map(|i| (i * 2654435761usize % (1 << 30)) as i32)
                .collect();
            let bounds: Vec<i32> = (0..=qt).map(|i| ((i << 30) / qt) as i32).collect();
            let kl = Runtime::lit_i32(&keys, &[m.map_batch, m.keys_per_file]).unwrap();
            let bl = Runtime::lit_i32(&bounds, &[qt + 1]).unwrap();
            bench_fn("map_histogram (16x512 keys, 96 buckets)", &cfg, || {
                rt.execute_to_i32("map_histogram", &[kl.clone(), bl.clone()]).unwrap()
            });

            let ivs: Vec<f32> = (0..m.reduce_batch * m.t).map(|i| i as f32).collect();
            let il = Runtime::lit_f32(&ivs, &[m.reduce_batch, m.t]).unwrap();
            bench_fn("reduce_sum (16x32)", &cfg, || {
                rt.execute_to_f32("reduce_sum", &[il.clone()]).unwrap()
            });

            let a: Vec<i32> = (0..8 * 128).map(|i| i as i32).collect();
            let al = Runtime::lit_i32(&a, &[8, 128]).unwrap();
            bench_fn("xor_blocks (8x128 i32)", &cfg, || {
                rt.execute_to_i32("xor_blocks", &[al.clone(), al.clone()]).unwrap()
            });
            println!(
                "\nnote: PJRT dispatch overhead dominates at these sizes; the Rust-native\n\
                 XOR above is the shuffle hot path precisely because of this (DESIGN.md §6)."
            );
        }
        Err(e) => println!("\n[skipping PJRT section: {e}]"),
    }
}
