//! E1 — Reproduce the paper's worked example (Figs 2 & 3).
//!
//! `(M1,M2,M3,N) = (6,7,7,12)`:
//!   * uncoded:              L = 16
//!   * Fig-2 sequential:     L = 13 (suboptimal coding-aware allocation)
//!   * Fig-3 / Theorem 1:    L = 12 (25% below uncoded)
//!
//! Each number is produced twice: analytically (Lemma 1 on the allocation)
//! and by the byte-level engine (real Map compute, XOR shuffle, decode,
//! oracle-verified Reduce).

use hetcdc::bench::{bench_fn, section, table, Bench};
use hetcdc::coding::plan::{plan_k3, plan_uncoded};
use hetcdc::engine::{Engine, NativeBackend};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::placement::alloc::Allocation;
use hetcdc::placement::k3::optimal_allocation;
use hetcdc::placement::lemma1;
use hetcdc::theory::load;
use hetcdc::theory::params::Params3;

/// Fig 2's sequential allocation (node3 = files 2..8, 1-indexed).
fn fig2_allocation() -> Allocation {
    let mut holders = vec![0u32; 12];
    for f in 0..6 {
        holders[f] |= 0b001;
    }
    holders[0] |= 0b010;
    for f in 6..12 {
        holders[f] |= 0b010;
    }
    for f in 1..8 {
        holders[f] |= 0b100;
    }
    Allocation::new(3, 1, holders)
}

fn bench_cluster_job(storage: [u64; 3], n: u64) -> (ClusterSpec, JobSpec) {
    let mut cluster = ClusterSpec::homogeneous(3, 1, 1000.0);
    for (node, m) in cluster.nodes.iter_mut().zip(storage) {
        node.storage = m;
    }
    let mut job = JobSpec::terasort(n);
    job.t = 16;
    job.keys_per_file = 64;
    (cluster, job)
}

fn engine_load(storage: [u64; 3], n: u64, placer: &str, mode: ShuffleMode) -> f64 {
    let (cluster, job) = bench_cluster_job(storage, n);
    let mut be = NativeBackend;
    let r = Engine::new(&cluster, &job, &mut be)
        .run(placer, mode)
        .expect("engine run");
    assert!(r.verified, "oracle verification failed");
    r.load_equations
}

fn engine_load_custom(storage: [u64; 3], n: u64, alloc: &Allocation, mode: ShuffleMode) -> f64 {
    let (cluster, job) = bench_cluster_job(storage, n);
    let mut be = NativeBackend;
    let r = Engine::new(&cluster, &job, &mut be)
        .run_custom(alloc, mode)
        .expect("engine run");
    assert!(r.verified, "oracle verification failed");
    r.load_equations
}

fn main() {
    let p = Params3::new(6, 7, 7, 12).unwrap();
    section("E1: paper worked example (M1,M2,M3,N) = (6,7,7,12)");

    let fig2 = fig2_allocation();
    let fig3 = optimal_allocation(&p);
    let rows = vec![
        vec![
            "uncoded (any allocation)".into(),
            format!("{}", load::uncoded(&p)),
            format!("{}", engine_load([6, 7, 7], 12, "optimal-k3", ShuffleMode::Uncoded)),
            "3N − M = 16".into(),
        ],
        vec![
            "Fig 2: sequential allocation + coding".into(),
            format!("{}", lemma1::load_units(&fig2)),
            format!("{}", engine_load_custom([6, 7, 7], 12, &fig2, ShuffleMode::Coded)),
            "13".into(),
        ],
        vec![
            "Fig 3: optimal allocation + coding".into(),
            format!("{}", plan_k3(&fig3).load_equations(&fig3)),
            format!("{}", engine_load([6, 7, 7], 12, "optimal-k3", ShuffleMode::Coded)),
            "L* = 12".into(),
        ],
    ];
    table(
        &["scheme", "analytic L", "engine-measured L", "paper"],
        &rows,
    );
    println!(
        "\nsaving vs uncoded: {} IVs ({:.0}%)  — paper: \"25% lower\"",
        load::saving(&p),
        100.0 * load::saving(&p) / load::uncoded(&p)
    );

    // Sanity gates: fail loudly if any headline number drifts.
    assert_eq!(load::uncoded(&p), 16.0);
    assert_eq!(lemma1::load_units(&fig2), 13);
    assert_eq!(load::lstar(&p), 12.0);

    section("timing");
    let cfg = Bench::default();
    bench_fn("optimal_allocation(6,7,7,12)", &cfg, || optimal_allocation(&p));
    bench_fn("plan_k3 on optimal allocation", &cfg, || plan_k3(&fig3));
    bench_fn("plan_uncoded on optimal allocation", &cfg, || {
        plan_uncoded(&fig3)
    });
    bench_fn("lemma1::load_units(fig2)", &cfg, || {
        lemma1::load_units(&fig2)
    });
}
