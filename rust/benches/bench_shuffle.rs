//! E6/E7 — end-to-end coded vs uncoded shuffle on the simulated
//! heterogeneous cluster (the CodedTeraSort-style evaluation [10] that the
//! paper's introduction motivates).
//!
//! E6: TeraSort on an EC2-like 3-node cluster — measured shuffle bytes,
//! simulated phase times, and the coded/uncoded ratio vs theory.
//! E7: WordCount — fraction of job time spent shuffling (the §I 33–70%
//! motivation) with and without coding.

use hetcdc::bench::{bench_fn, section, table, Bench};
use hetcdc::engine::{
    Engine, ExecConfig, ExecMode, Executor, JobBuilder, NativeBackend, PlanCache, XlaBackend,
};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::runtime::Runtime;
use hetcdc::theory::load;
use hetcdc::util::stats::fmt_bytes;

fn run(
    cluster: &ClusterSpec,
    job: &JobSpec,
    placer: &str,
    mode: ShuffleMode,
) -> hetcdc::engine::RunReport {
    let mut be = NativeBackend;
    let r = Engine::new(cluster, job, &mut be)
        .run(placer, mode)
        .expect("engine");
    assert!(r.verified, "oracle verification failed");
    r
}

fn main() {
    let n = 60u64;
    let cluster = ClusterSpec::ec2_like_3node(n);
    let p = cluster.params3(n).unwrap();

    section("E6: TeraSort, EC2-like heterogeneous 3-node cluster");
    println!(
        "cluster: {:?} storage={:?} N={n}",
        cluster.nodes.iter().map(|x| x.name.as_str()).collect::<Vec<_>>(),
        cluster.storage()
    );
    let job = JobSpec::terasort(n);
    let coded = run(&cluster, &job, "optimal-k3", ShuffleMode::Coded);
    let uncoded = run(&cluster, &job, "optimal-k3", ShuffleMode::Uncoded);
    let rows = vec![
        vec![
            "coded (Theorem 1)".into(),
            format!("{}", coded.load_equations),
            fmt_bytes(coded.payload_bytes as f64),
            format!("{}", coded.messages),
            format!("{:.4}s", coded.shuffle_time_s),
            format!("{:.4}s", coded.job_time_s),
        ],
        vec![
            "uncoded".into(),
            format!("{}", uncoded.load_equations),
            fmt_bytes(uncoded.payload_bytes as f64),
            format!("{}", uncoded.messages),
            format!("{:.4}s", uncoded.shuffle_time_s),
            format!("{:.4}s", uncoded.job_time_s),
        ],
    ];
    table(
        &["mode", "load (IV eq)", "payload", "msgs", "shuffle t", "job t"],
        &rows,
    );
    println!(
        "\nload ratio uncoded/coded = {:.3} (theory {:.3}); shuffle-time speedup {:.2}x",
        uncoded.load_equations / coded.load_equations,
        load::uncoded(&p) / load::lstar(&p),
        uncoded.shuffle_time_s / coded.shuffle_time_s,
    );
    assert_eq!(coded.load_equations, load::lstar(&p));
    assert_eq!(uncoded.load_equations, load::uncoded(&p));

    section("E7: WordCount — shuffle fraction of job time (the §I 33–70% story)");
    let wjob = JobSpec::wordcount(n);
    let wc = run(&cluster, &wjob, "optimal-k3", ShuffleMode::Coded);
    let wu = run(&cluster, &wjob, "optimal-k3", ShuffleMode::Uncoded);
    table(
        &["mode", "map t", "shuffle t", "shuffle % of job"],
        &vec![
            vec![
                "coded".into(),
                format!("{:.4}s", wc.map_time_s),
                format!("{:.4}s", wc.shuffle_time_s),
                format!("{:.0}%", 100.0 * wc.shuffle_fraction()),
            ],
            vec![
                "uncoded".into(),
                format!("{:.4}s", wu.map_time_s),
                format!("{:.4}s", wu.shuffle_time_s),
                format!("{:.0}%", 100.0 * wu.shuffle_fraction()),
            ],
        ],
    );

    section("homogeneous baseline (Li et al. [2]), K=3 r=2, N=60");
    let hcluster = ClusterSpec::homogeneous(3, 40, 750.0);
    let hjob = JobSpec::terasort(60);
    let hc = run(&hcluster, &hjob, "homogeneous", ShuffleMode::Coded);
    let hu = run(&hcluster, &hjob, "homogeneous", ShuffleMode::Uncoded);
    println!(
        "coded {} vs uncoded {} IV equations (theory: {} vs {})",
        hc.load_equations,
        hu.load_equations,
        hetcdc::theory::homogeneous::load_at_r(3, 2, 60),
        60,
    );

    section("E10 (ablation): heterogeneity-aware vs storage-oblivious placement");
    // The §I motivation ([13]): homogeneous-assumption algorithms lose
    // badly on heterogeneous clusters. Oblivious = provision all nodes to
    // min storage, run the homogeneous scheme.
    let mut arows = Vec::new();
    for storage in [[4u64, 8, 12], [6, 7, 7], [4, 12, 12], [5, 10, 12]] {
        let mut cl = ClusterSpec::homogeneous(3, 1, 1000.0);
        for (node, &m) in cl.nodes.iter_mut().zip(storage.iter()) {
            node.storage = m;
        }
        let jb = JobSpec::terasort(12);
        let aware = run(&cl, &jb, "optimal-k3", ShuffleMode::Coded);
        let obliv = run(&cl, &jb, "oblivious", ShuffleMode::Coded);
        arows.push(vec![
            format!("{storage:?}"),
            format!("{}", aware.load_equations),
            format!("{}", obliv.load_equations),
            format!("{:.2}x", obliv.load_equations / aware.load_equations.max(1e-12)),
        ]);
    }
    table(
        &["storage (N=12)", "aware L (Thm 1)", "oblivious L", "penalty"],
        &arows,
    );

    // XLA backend, if artifacts are present: the production path.
    match Runtime::load(Runtime::default_dir()) {
        Ok(mut rt) => {
            section("E6b: same TeraSort job through the XLA/PJRT backend");
            let m = rt.manifest.clone();
            let mut xjob = JobSpec::terasort(n);
            xjob.t = m.t;
            xjob.keys_per_file = m.keys_per_file;
            let mut be = XlaBackend::new(&mut rt);
            let r = Engine::new(&cluster, &xjob, &mut be)
                .run("optimal-k3", ShuffleMode::Coded)
                .expect("xla engine");
            assert!(r.verified);
            println!(
                "XLA coded load {} (== native {}), exact integer match: max_abs_err = {}",
                r.load_equations, coded.load_equations, r.max_abs_err
            );
            let xcfg = Bench {
                measure: std::time::Duration::from_millis(2000),
                ..Bench::default()
            };
            bench_fn("terasort N=60 coded e2e (XLA backend)", &xcfg, || {
                let mut be = XlaBackend::new(&mut rt);
                Engine::new(&cluster, &xjob, &mut be)
                    .run("optimal-k3", ShuffleMode::Coded)
                    .expect("xla engine")
                    .payload_bytes
            });
        }
        Err(e) => println!("\n[skipping XLA section: {e}]"),
    }

    section("timing (native backend, end-to-end jobs)");
    let cfg = Bench {
        measure: std::time::Duration::from_millis(1500),
        ..Bench::default()
    };
    bench_fn("terasort N=60 coded e2e", &cfg, || {
        run(&cluster, &job, "optimal-k3", ShuffleMode::Coded).payload_bytes
    });
    bench_fn("terasort N=60 uncoded e2e", &cfg, || {
        run(&cluster, &job, "optimal-k3", ShuffleMode::Uncoded).payload_bytes
    });
    let wjob2 = JobSpec::wordcount(n);
    bench_fn("wordcount N=60 coded e2e", &cfg, || {
        run(&cluster, &wjob2, "optimal-k3", ShuffleMode::Coded).payload_bytes
    });

    section("staged pipeline: plan reuse vs plan-per-run (repeated jobs)");
    // The heavy-traffic path: the same job shape arrives over and over
    // with fresh data. Plan-per-run re-derives the Theorem-1 placement,
    // rebuilds the shuffle plan, and re-verifies decodability every batch;
    // the staged pipeline builds the Plan once and only moves bytes.
    let mut be = NativeBackend;
    let mut batch_seed = job.seed;
    let per_run = bench_fn("plan-per-run (build + verify every batch)", &cfg, || {
        batch_seed = batch_seed.wrapping_add(1);
        let plan = JobBuilder::new(&cluster, &job)
            .placer("optimal-k3")
            .mode(ShuffleMode::Coded)
            .build()
            .expect("plan");
        let mut exec =
            Executor::with_config(&plan, ExecConfig::default()).expect("executor");
        let r = exec.run_batch(&mut be, batch_seed).expect("run");
        assert!(r.verified);
        r.payload_bytes
    });
    let plan = JobBuilder::new(&cluster, &job)
        .placer("optimal-k3")
        .mode(ShuffleMode::Coded)
        .build()
        .expect("plan");
    let mut exec = Executor::with_config(&plan, ExecConfig::default()).expect("executor");
    let reused = bench_fn("plan reuse (one Plan, one Executor)", &cfg, || {
        batch_seed = batch_seed.wrapping_add(1);
        let r = exec.run_batch(&mut be, batch_seed).expect("run");
        assert!(r.verified);
        r.payload_bytes
    });
    println!(
        "\nplan reuse speedup: {:.2}x over plan-per-run ({} batches run against one plan)",
        per_run.mean_ns / reused.mean_ns,
        exec.batches_run()
    );
    if reused.mean_ns >= per_run.mean_ns {
        // Soft check: timing noise on a loaded machine should not abort
        // the whole bench run, but a genuine regression must be loud.
        println!("WARNING: plan reuse did not beat plan-per-run — investigate");
    }

    section("sharded executor: serial vs parallel batches of one plan");
    // Same plan, same seeds; results are bit-identical (asserted by
    // tier-1 tests) — only the wall-clock may differ.
    let serial_t = bench_fn("executor e2e (serial)", &cfg, || {
        batch_seed = batch_seed.wrapping_add(1);
        let r = exec.run_batch(&mut be, batch_seed).expect("serial batch");
        assert!(r.verified);
        r.payload_bytes
    });
    let mut par_exec = Executor::with_config(&plan, ExecConfig::default().mode(ExecMode::Parallel))
        .expect("parallel executor");
    let par_t = bench_fn("executor e2e (parallel, auto threads)", &cfg, || {
        batch_seed = batch_seed.wrapping_add(1);
        let r = par_exec.run_batch(&mut be, batch_seed).expect("parallel batch");
        assert!(r.verified);
        r.payload_bytes
    });
    println!(
        "\nsharded executor speedup: {:.2}x over serial ({} worker threads)",
        serial_t.mean_ns / par_t.mean_ns,
        par_exec.effective_threads()
    );

    section("batch pipelining: steady-state batches/sec (Map i+1 overlaps Shuffle i)");
    // The serving-throughput view: with a stream of batches against one
    // plan, the figure of merit is batches/sec, not single-batch latency.
    // Pipelined results are bit-identical to serial (tier-1 asserted);
    // only the steady-state rate changes. K ∈ {3, 5, 8} from the
    // deterministic suite's coded scenarios.
    const PIPE_BATCHES: u64 = 8;
    let mut prows = Vec::new();
    for name in ["k3-terasort-coded", "k5-terasort-coded", "k8-terasort-coded"] {
        let Some(sc) = hetcdc::bench::default_suite().into_iter().find(|s| s.name == name)
        else {
            eprintln!("WARNING: suite scenario '{name}' missing; skipping");
            continue;
        };
        let pcluster = sc.cluster();
        let pjob = sc.job();
        let pplan = JobBuilder::new(&pcluster, &pjob)
            .placer(sc.placer)
            .mode(sc.mode)
            .build()
            .expect("suite plan");
        let seeds: Vec<u64> = (0..PIPE_BATCHES).map(|b| pjob.seed.wrapping_add(b)).collect();
        let mut pbe = NativeBackend;
        let mut sexec =
            Executor::with_config(&pplan, ExecConfig::default()).expect("serial executor");
        let st = bench_fn(&format!("{name} serial x{PIPE_BATCHES}"), &cfg, || {
            sexec.run_batches(&mut pbe, &seeds).expect("serial batches").len()
        });
        let mut pexec =
            Executor::with_config(&pplan, ExecConfig::default().mode(ExecMode::Pipelined))
                .expect("pipelined executor");
        let pt = bench_fn(&format!("{name} pipelined x{PIPE_BATCHES}"), &cfg, || {
            pexec.run_batches(&mut pbe, &seeds).expect("pipelined batches").len()
        });
        // One timed iteration runs PIPE_BATCHES batches.
        let serial_bps = PIPE_BATCHES as f64 * st.throughput_per_s();
        let piped_bps = PIPE_BATCHES as f64 * pt.throughput_per_s();
        prows.push(vec![
            name.to_string(),
            format!("{}", pcluster.k()),
            format!("{serial_bps:.1}"),
            format!("{piped_bps:.1}"),
            format!("{:.2}x", piped_bps / serial_bps.max(1e-12)),
        ]);
    }
    table(
        &["scenario", "K", "serial batches/s", "pipelined batches/s", "speedup"],
        &prows,
    );

    section("plan build: serial vs threaded JobBuilder at K ∈ {8, 12, 16}");
    // The plan-construction path is what `--threads` parallelizes now:
    // sharded LP enumeration/pricing, parallel grid group/round
    // construction, and the per-node worklist decode verification. Built
    // plans are byte-identical at every thread count (asserted below) —
    // only the build wall-clock changes.
    let build_threads = hetcdc::engine::resolve_threads(0);
    let hw_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if hw_threads >= 2 {
        assert!(
            build_threads >= 2,
            "threaded plan builds must exercise >= 2 workers on a multicore host"
        );
    }
    let mut brows = Vec::new();
    for name in [
        "k8-terasort-combinatorial",
        "k12-terasort-combinatorial",
        "k16-terasort-combinatorial",
    ] {
        let Some(sc) = hetcdc::bench::default_suite().into_iter().find(|s| s.name == name)
        else {
            eprintln!("WARNING: suite scenario '{name}' missing; skipping");
            continue;
        };
        let bcluster = sc.cluster();
        let bjob = sc.job();
        let build = |threads: usize| {
            JobBuilder::new(&bcluster, &bjob)
                .placer(sc.placer)
                .mode(sc.mode)
                .threads(threads)
                .build()
                .expect("suite plan builds")
        };
        assert_eq!(
            build(1).to_json_string(),
            build(0).to_json_string(),
            "{name}: threaded plan build must be byte-identical to serial"
        );
        let sname = format!("{name} plan build (serial)");
        let st = bench_fn(&sname, &cfg, || build(1).predicted.messages);
        let tname = format!("{name} plan build ({build_threads} threads)");
        let tt = bench_fn(&tname, &cfg, || build(0).predicted.messages);
        brows.push(vec![
            name.to_string(),
            format!("{}", bcluster.k()),
            format!("{:.0}", st.mean_ns / 1e3),
            format!("{:.0}", tt.mean_ns / 1e3),
            format!("{:.2}x", st.mean_ns / tt.mean_ns.max(1.0)),
        ]);
    }
    table(
        &["scenario", "K", "serial µs/build", "threaded µs/build", "speedup"],
        &brows,
    );
    println!("(threaded builds used {build_threads} worker threads; plans byte-identical)");

    // PlanCache: the same comparison when job shapes interleave.
    let mut cache = PlanCache::new(16);
    let shapes: Vec<JobSpec> = vec![JobSpec::terasort(n), JobSpec::wordcount(n)];
    let cached = bench_fn("PlanCache get_or_build + run (2 shapes)", &cfg, || {
        batch_seed = batch_seed.wrapping_add(1);
        let jb = &shapes[(batch_seed % 2) as usize];
        let plan = cache
            .get_or_build(&cluster, jb, "optimal-k3", None, ShuffleMode::Coded)
            .expect("cached plan");
        let mut exec =
            Executor::with_config(&plan, ExecConfig::default()).expect("executor");
        let r = exec.run_batch(&mut be, batch_seed).expect("run");
        assert!(r.verified);
        r.payload_bytes
    });
    println!(
        "cache: {} hits / {} misses ({:.2}x over plan-per-run)",
        cache.hits,
        cache.misses,
        per_run.mean_ns / cached.mean_ns
    );
}
