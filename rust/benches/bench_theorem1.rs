//! E2/E3 — Theorem 1 across the whole parameter space.
//!
//! For every sorted `(M1 <= M2 <= M3, N)` grid point this regenerates the
//! paper's main result table: closed-form `L*`, the Lemma-1 load of the
//! constructed placement (achievability), the best §IV converse bound, and
//! the uncoded baseline — asserting achievability == converse == `L*`
//! everywhere. Section 2 reproduces Remark 2 (homogeneous reduction to
//! Li et al. [2]).

use hetcdc::bench::{bench_fn, section, table, Bench};
use hetcdc::coding::plan::plan_k3;
use hetcdc::placement::k3::optimal_allocation;
use hetcdc::placement::lemma1::load_units;
use hetcdc::theory::params::Params3;
use hetcdc::theory::{converse, homogeneous, load};

fn main() {
    section("E2: L* vs achievability vs converse (exhaustive grids)");
    let mut rows = Vec::new();
    let mut checked = 0u64;
    let mut regime_counts = std::collections::BTreeMap::new();
    for n in [6u64, 12, 24, 36] {
        for m1 in 1..=n {
            for m2 in m1..=n {
                for m3 in m2..=n {
                    let Ok(p) = Params3::new(m1, m2, m3, n) else {
                        continue;
                    };
                    let lstar2 = load::lstar_half(&p);
                    let alloc = optimal_allocation(&p);
                    let achieved = load_units(&alloc);
                    let bound = converse::bounds_half(&p).max_half();
                    assert_eq!(
                        achieved, lstar2,
                        "{p}: achievability {achieved} != L*half {lstar2}"
                    );
                    assert_eq!(bound, lstar2, "{p}: converse {bound} != L*half {lstar2}");
                    assert!(lstar2 <= load::uncoded_half(&p));
                    *regime_counts.entry(load::classify(&p)).or_insert(0u64) += 1;
                    checked += 1;
                }
            }
        }
    }
    println!(
        "verified L* == constructed-placement load == max(converse) on {checked} parameter points"
    );
    for (regime, count) in &regime_counts {
        rows.push(vec![format!("{regime}"), count.to_string()]);
    }
    table(&["regime", "grid points"], &rows);

    // Representative rows (one per regime, N = 12).
    section("representative rows (N = 12)");
    let reps = [
        (4u64, 5, 6),
        (6, 7, 7),
        (8, 8, 8),
        (2, 3, 12),
        (5, 8, 11),
        (10, 10, 10),
        (5, 11, 11),
    ];
    let mut rrows = Vec::new();
    for (m1, m2, m3) in reps {
        let p = Params3::new(m1, m2, m3, 12).unwrap();
        rrows.push(vec![
            format!("({m1},{m2},{m3},12)"),
            format!("{}", load::classify(&p)),
            format!("{}", load::lstar(&p)),
            format!("{}", load::uncoded(&p)),
            format!("{:.1}%", 100.0 * load::saving(&p) / load::uncoded(&p).max(1e-12)),
        ]);
    }
    table(&["params", "regime", "L*", "uncoded", "saving"], &rrows);

    section("E3: Remark 2 — homogeneous reduction to Li et al. [2]");
    let n = 12u64;
    let mut hrows = Vec::new();
    for m in 4..=12u64 {
        let p = Params3::new(m, m, m, n).unwrap();
        let r = 3.0 * m as f64 / n as f64;
        let env = homogeneous::load_envelope(3, r, n);
        assert!((load::lstar(&p) - env).abs() < 1e-9, "Remark 2 violated at m={m}");
        hrows.push(vec![
            format!("{m}"),
            format!("{r:.2}"),
            format!("{}", load::lstar(&p)),
            format!("{env}"),
        ]);
    }
    table(&["M (each node)", "r = 3M/N", "L* (Thm 1)", "[2] envelope"], &hrows);

    section("timing");
    let cfg = Bench::default();
    let p = Params3::new(6, 7, 7, 12).unwrap();
    bench_fn("classify + lstar", &cfg, || {
        (load::classify(&p), load::lstar_half(&p))
    });
    bench_fn("converse bounds", &cfg, || converse::bounds_half(&p));
    bench_fn("construct + measure placement", &cfg, || {
        let a = optimal_allocation(&p);
        load_units(&a)
    });
    let big = Params3::new(600, 700, 700, 1200).unwrap();
    bench_fn("placement N=1200 (2400 subfiles)", &cfg, || {
        let a = optimal_allocation(&big);
        plan_k3(&a).load_units()
    });
}
