//! Regenerate every number the paper reports, in one run:
//!   * Fig 1 setting (system model) — the engine's Q = K = 3 job
//!   * Fig 2 / Fig 3 worked example — 16 / 13 / 12
//!   * Theorem 1 — all seven regimes with their L* formulas
//!   * Figs 5–11 — the per-regime subset cardinalities (eqs. 12/15/18/21/25)
//!   * Remark 1/2 — savings and the homogeneous reduction
//!   * §V — the K=3 LP equivalence and the K=4 example's 3 collections

use hetcdc::coding::plan::plan_k3;
use hetcdc::placement::alloc::Allocation;
use hetcdc::placement::k3::optimal_allocation;
use hetcdc::placement::lemma1::{load_units, Sizes3};
use hetcdc::placement::lp_general::{perfect_collections, solve_general, DEFAULT_COLLECTION_CAP};
use hetcdc::theory::params::{Params3, ParamsK};
use hetcdc::theory::{converse, homogeneous, load};

fn main() {
    println!("================================================================");
    println!(" On Heterogeneous Coded Distributed Computing — number-by-number");
    println!("================================================================\n");

    // ---- Fig 2 / Fig 3 worked example.
    println!("§III worked example, (M1,M2,M3,N) = (6,7,7,12):");
    let p = Params3::new(6, 7, 7, 12).unwrap();
    println!("  uncoded                        L = {}   (paper: 16)", load::uncoded(&p));
    let mut fig2 = vec![0u32; 12];
    (0..6).for_each(|f| fig2[f] |= 0b001);
    fig2[0] |= 0b010;
    (6..12).for_each(|f| fig2[f] |= 0b010);
    (1..8).for_each(|f| fig2[f] |= 0b100);
    let fig2 = Allocation::new(3, 1, fig2);
    println!("  Fig 2 sequential + coding      L = {}   (paper: 13)", load_units(&fig2));
    println!("  Fig 3 optimal allocation       L = {}   (paper: L* = 12)\n", load::lstar(&p));

    // ---- Theorem 1, regime by regime.
    println!("Theorem 1 — regimes and closed forms (N = 12 examples):");
    let cases = [
        ((4u64, 5, 6), "7N/2 - 3M/2"),
        ((5, 5, 4), "7N/2 - 3M/2"),
        ((8, 8, 8), "7N/2 - 3M/2"),
        ((2, 3, 12), "3N - (M1+M)"),
        ((5, 8, 11), "3N - (M1+M)"),
        ((10, 10, 10), "3N/2 - M/2"),
        ((5, 11, 11), "N - M1"),
    ];
    for ((m1, m2, m3), formula) in cases {
        let pp = Params3::new(m1, m2, m3, 12).unwrap();
        let alloc = optimal_allocation(&pp);
        let plan = plan_k3(&alloc);
        assert_eq!(plan.load_equations(&alloc), load::lstar(&pp));
        println!(
            "  ({m1:>2},{m2:>2},{m3:>2},12)  {}  L* = {:>4}  [{}]  achieved by construction: {}",
            load::classify(&pp),
            load::lstar(&pp),
            formula,
            plan.load_equations(&alloc)
        );
    }

    // ---- Figs 5-11 subset cardinalities.
    println!("\nFigs 5–11 — subset cardinalities of the optimal placements");
    println!("(subfile units = 2x files; sorted storage):");
    let fig_cases =
        [(4u64, 5, 6), (4, 5, 5), (8, 8, 8), (2, 3, 12), (5, 8, 11), (10, 10, 10), (5, 11, 11)];
    for (m1, m2, m3) in fig_cases {
        let pp = Params3::new(m1, m2, m3, 12).unwrap();
        if pp.n != 12 {
            continue;
        }
        let s = Sizes3::of(&optimal_allocation(&pp));
        println!(
            "  ({m1:>2},{m2:>2},{m3:>2},12) {}: S1={} S2={} S3={} S12={} S13={} S23={} S123={}",
            load::classify(&pp),
            s.s1, s.s2, s.s3, s.s12, s.s13, s.s23, s.s123
        );
    }

    // ---- Converse (§IV).
    println!("\n§IV converse — L* equals the best of the four bounds everywhere:");
    let converse_cases = [(6u64, 7, 7, 12u64), (2, 3, 12, 12), (5, 11, 11, 12), (10, 10, 10, 12)];
    for (m1, m2, m3, n) in converse_cases {
        let pp = Params3::new(m1, m2, m3, n).unwrap();
        let b = converse::bounds_half(&pp);
        println!(
            "  ({m1},{m2},{m3},{n}): bounds/2 = {:?} -> max {} == L* {}",
            b.as_array().map(|x| x as f64 / 2.0),
            b.max_half() as f64 / 2.0,
            load::lstar(&pp)
        );
    }

    // ---- Remark 2.
    println!("\nRemark 2 — homogeneous reduction to Li et al. [2] (N = 12):");
    for m in [4u64, 6, 8, 10, 12] {
        let pp = Params3::new(m, m, m, 12).unwrap();
        let r = 3.0 * m as f64 / 12.0;
        println!(
            "  M = {m:>2} (r = {r:.1}): L* = {:>4}  envelope([2]) = {:>4}",
            load::lstar(&pp),
            homogeneous::load_envelope(3, r, 12)
        );
    }

    // ---- §V.
    println!("\n§V — algorithmic achievability:");
    let pk = ParamsK::new(vec![6, 7, 7], 12).unwrap();
    let sol = solve_general(&pk, DEFAULT_COLLECTION_CAP).unwrap();
    println!(
        "  K=3 LP on (6,7,7,12): load = {} (Remark 5: equals Theorem 1's 12)",
        sol.load
    );
    let (colls, _) = perfect_collections(4, 2, 100);
    println!(
        "  K=4, j=2 perfect collections: {} (paper Step 2 lists exactly 3):",
        colls.len()
    );
    for coll in &colls {
        let names: Vec<String> = coll
            .iter()
            .map(|m| {
                let nodes: Vec<String> = (0..4)
                    .filter(|i| m & (1 << i) != 0)
                    .map(|i| (i + 1).to_string())
                    .collect();
                format!("({})", nodes.join(","))
            })
            .collect();
        println!("    {{{}}}", names.join(","));
    }
    let pk4 = ParamsK::new(vec![5, 5, 5, 5], 10).unwrap();
    let sol4 = solve_general(&pk4, DEFAULT_COLLECTION_CAP).unwrap();
    println!(
        "  K=4 homogeneous r=2: LP load = {} ([2]: N(K-r)/r = 10)",
        sol4.load
    );
    println!("\nAll assertions passed — every paper number reproduced.");
}
