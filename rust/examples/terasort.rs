//! End-to-end driver (DESIGN.md E6): coded TeraSort on a heterogeneous
//! 3-node cluster with the **XLA/PJRT backend** — the full three-layer
//! stack on a real workload.
//!
//! Pipeline: Theorem-1 placement -> Map via the `map_histogram` Pallas/XLA
//! artifact -> XOR-coded shuffle over the simulated broadcast network ->
//! Reduce -> verification against the single-node oracle. Reports the
//! paper's headline metric: communication-load reduction vs uncoded.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example terasort
//! ```

use hetcdc::engine::{Engine, NativeBackend, XlaBackend};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::runtime::Runtime;
use hetcdc::theory::load;
use hetcdc::util::stats::fmt_bytes;

fn main() {
    let n_files = 120u64;
    let cluster = ClusterSpec::ec2_like_3node(n_files);
    let p = cluster.params3(n_files).expect("params");

    println!("== Coded TeraSort on a heterogeneous cluster ==");
    for node in &cluster.nodes {
        println!(
            "  {:<12} storage {:>3} files  uplink {:>5} Mbit/s  map {:>4} files/s",
            node.name, node.storage, node.uplink_mbps, node.map_files_per_s
        );
    }
    println!(
        "  N = {n_files} files, Theorem-1 regime {}, L* = {} (uncoded {})\n",
        load::classify(&p),
        load::lstar(&p),
        load::uncoded(&p)
    );

    // Prefer the XLA backend; fall back to native with a note.
    let mut rt = match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("[artifacts unavailable -> native backend] {e}\n");
            None
        }
    };

    let mut job = JobSpec::terasort(n_files);
    if let Some(rt) = &rt {
        job.t = rt.manifest.t;
        job.keys_per_file = rt.manifest.keys_per_file;
    }

    let mut results = Vec::new();
    for mode in [ShuffleMode::Coded, ShuffleMode::Uncoded] {
        let report = match rt.as_mut() {
            Some(rt) => {
                let mut be = XlaBackend::new(rt);
                Engine::new(&cluster, &job, &mut be)
                    .run("optimal-k3", mode)
                    .expect("run")
            }
            None => {
                let mut be = NativeBackend;
                Engine::new(&cluster, &job, &mut be)
                    .run("optimal-k3", mode)
                    .expect("run")
            }
        };
        assert!(report.verified, "reduce output mismatch vs oracle");
        println!(
            "{:?} ({} backend):",
            mode, report.backend
        );
        println!(
            "  shuffle load    {} IV equations ({} payload, {} on the wire, {} msgs)",
            report.load_equations,
            fmt_bytes(report.payload_bytes as f64),
            fmt_bytes(report.wire_bytes as f64),
            report.messages
        );
        println!(
            "  simulated time  map {:.3}s + shuffle {:.3}s = {:.3}s  (shuffle = {:.0}% of job)",
            report.map_time_s,
            report.shuffle_time_s,
            report.job_time_s,
            100.0 * report.shuffle_fraction()
        );
        println!("  verified        true (all reducer outputs == single-node oracle)\n");
        results.push(report);
    }

    let (coded, uncoded) = (&results[0], &results[1]);
    println!("== headline ==");
    println!(
        "communication load: {} -> {} IV equations ({:.1}% reduction; theory {:.1}%)",
        uncoded.load_equations,
        coded.load_equations,
        100.0 * (uncoded.load_equations - coded.load_equations) / uncoded.load_equations,
        100.0 * load::saving(&p) / load::uncoded(&p),
    );
    println!(
        "shuffle time:       {:.3}s -> {:.3}s ({:.2}x faster)",
        uncoded.shuffle_time_s,
        coded.shuffle_time_s,
        uncoded.shuffle_time_s / coded.shuffle_time_s
    );
    assert_eq!(coded.load_equations, load::lstar(&p), "engine must hit L*");
}
