//! Heterogeneous-cluster design sweep: the §V LP as a capacity-planning
//! tool across mixed EC2-style instance fleets (K = 3..6).
//!
//! For each fleet, computes the LP-optimal placement and compares the
//! predicted coded load against the uncoded baseline, then executes the
//! realized placement in the engine (greedy pairing coder) to show the
//! measured load and simulated shuffle time on heterogeneous uplinks.

use hetcdc::engine::{Engine, NativeBackend};
use hetcdc::model::cluster::{ClusterSpec, NodeSpec};
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::placement::lp_general::{solve_general, DEFAULT_COLLECTION_CAP};
use hetcdc::theory::params::ParamsK;

fn node(name: &str, storage: u64, mbps: f64, rate: f64) -> NodeSpec {
    NodeSpec {
        name: name.into(),
        storage,
        uplink_mbps: mbps,
        map_files_per_s: rate,
    }
}

fn fleet(k: usize) -> ClusterSpec {
    // Mixed instance types; storage scales with instance size.
    let catalog = [
        ("m4.large", 4u64, 450.0, 120.0),
        ("m4.xlarge", 6, 750.0, 240.0),
        ("m4.2xlarge", 8, 1000.0, 480.0),
        ("c4.xlarge", 5, 750.0, 320.0),
        ("r4.xlarge", 7, 750.0, 200.0),
        ("m4.4xlarge", 10, 2000.0, 900.0),
    ];
    ClusterSpec {
        nodes: catalog[..k]
            .iter()
            .map(|(n, s, b, r)| node(n, *s, *b, *r))
            .collect(),
        latency_ms: 0.5,
        topology: hetcdc::net::Topology::Shared,
        faults: hetcdc::net::FaultSpec::default(),
    }
}

fn main() {
    let n_files = 12u64;
    println!("== §V LP design sweep over mixed instance fleets (N = {n_files}) ==\n");
    println!(
        "{:<3} {:<38} {:>9} {:>9} {:>10} {:>10} {:>9}",
        "K", "fleet (storage)", "LP load", "uncoded", "engine L", "shuffle s", "saving"
    );

    for k in 3..=6usize {
        let cluster = fleet(k);
        let storage = cluster.storage();
        let p = match ParamsK::new(storage.clone(), n_files) {
            Ok(p) => p,
            Err(e) => {
                println!("K={k}: skipped ({e})");
                continue;
            }
        };
        let sol = solve_general(&p, DEFAULT_COLLECTION_CAP).expect("LP");
        let uncoded = (k as u64 * n_files - p.total()) as f64;

        // Execute the realized placement end-to-end.
        let mut job = JobSpec::terasort(n_files);
        job.t = 16;
        job.keys_per_file = 128;
        let mut be = NativeBackend;
        let mut engine = Engine::new(&cluster, &job, &mut be);
        let coded = engine
            .run("lp-general", ShuffleMode::Coded)
            .expect("coded run");
        assert!(coded.verified);

        let names: Vec<String> = cluster
            .nodes
            .iter()
            .map(|nd| {
                let short = nd
                    .name
                    .trim_start_matches("m4.")
                    .trim_start_matches("c4.")
                    .trim_start_matches("r4.");
                format!("{}:{}", short, nd.storage)
            })
            .collect();
        println!(
            "{:<3} {:<38} {:>9.2} {:>9.1} {:>10.2} {:>10.4} {:>8.0}%",
            k,
            names.join(","),
            sol.load,
            uncoded,
            coded.load_equations,
            coded.shuffle_time_s,
            100.0 * (uncoded - coded.load_equations) / uncoded,
        );
        for (j, d) in &sol.dropped {
            println!("    note: j={j} dropped {d} collections at cap");
        }
    }

    println!(
        "\nLP load = paper's §V predicted total; engine L = byte-measured load of the\n\
         realized placement under the verified greedy pairing coder (== LP for K=3;\n\
         may sit between LP and uncoded for K>3 middle subsystems — DESIGN.md §9)."
    );
}
