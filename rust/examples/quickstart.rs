//! Quickstart: the staged pipeline on a small heterogeneous cluster —
//! build one validated `Plan`, then execute several data batches against
//! it with one reusable `Executor`.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use hetcdc::engine::{Engine, ExecConfig, ExecMode, Executor, JobBuilder, NativeBackend};
use hetcdc::model::cluster::ClusterSpec;
use hetcdc::model::job::{JobSpec, ShuffleMode};
use hetcdc::theory::load;

fn main() {
    // A 3-node cluster with heterogeneous storage: 6, 7 and 7 files of
    // capacity, processing N = 12 input files (the paper's Fig-3 example).
    let cluster = ClusterSpec::ec2_like_3node(12);
    let n_files = 12;
    let p = cluster.params3(n_files).expect("valid parameters");

    println!("cluster storage (M1,M2,M3) = {:?}, files N = {n_files}", cluster.storage());
    println!(
        "Theorem 1: regime {}, minimum load L* = {} IV equations",
        load::classify(&p),
        load::lstar(&p)
    );
    println!(
        "uncoded baseline: {} -> saving {:.0}%\n",
        load::uncoded(&p),
        100.0 * load::saving(&p) / load::uncoded(&p)
    );

    // Stage 1+2: JobBuilder -> Plan. Everything that depends only on
    // cluster/job shape (Theorem-1 placement, the XOR shuffle schedule,
    // decode verification, load prediction) happens exactly once here.
    let job = JobSpec::terasort(n_files);
    let plan = JobBuilder::new(&cluster, &job)
        .placer("optimal-k3")
        .mode(ShuffleMode::Coded)
        .build()
        .expect("plan build");
    println!(
        "plan: placer={} coder={} predicted load {} IV equations, {} broadcasts (fingerprint {:016x})",
        plan.placer,
        plan.coder,
        plan.predicted.load_equations,
        plan.predicted.messages,
        plan.fingerprint
    );

    // Stage 3: Executor — many data batches, one plan, reused buffers.
    let mut backend = NativeBackend;
    let mut exec = Executor::with_config(&plan, ExecConfig::default()).expect("executor");
    for batch in 0u64..3 {
        let r = exec.run_batch(&mut backend, job.seed + batch).expect("batch run");
        assert!(r.verified, "reduce outputs must match the single-node oracle");
        assert_eq!(r.load_equations, plan.predicted.load_equations);
        println!(
            "batch {batch} (seed {:#x}): load = {} IV equations, {} payload bytes, shuffle {:.1} ms (verified)",
            r.seed, r.load_equations, r.payload_bytes, r.shuffle_time_s * 1e3
        );
    }

    // Serving-path variant: pipelined batches — a worker thread Maps
    // batch i+1 while batch i shuffles (CLI: `hetcdc run --pipeline`).
    // Reports are bit-identical to the serial loop above; only the
    // steady-state batches/sec changes.
    let mut piped = Executor::with_config(&plan, ExecConfig::default().mode(ExecMode::Pipelined))
        .expect("executor");
    let seeds: Vec<u64> = (0..3).map(|b| job.seed + b).collect();
    let reports = piped.run_batches(&mut backend, &seeds).expect("pipelined batches");
    assert!(reports.iter().all(|r| r.verified));
    println!(
        "\npipelined: {} batches, every report identical to the serial run (mode={})",
        reports.len(),
        piped.mode().as_str()
    );

    // One-shot facade for the uncoded comparison.
    let r = Engine::new(&cluster, &job, &mut backend)
        .run("optimal-k3", ShuffleMode::Uncoded)
        .expect("uncoded run");
    println!(
        "\nuncoded baseline: load = {} IV equations ({} broadcasts)",
        r.load_equations, r.messages
    );
    println!("\nNext: examples/terasort.rs (full pipeline + XLA backend),");
    println!("      examples/paper_figures.rs (every number from the paper).");
}
